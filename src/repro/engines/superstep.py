"""Device-resident superstep engine (DESIGN.md §4b/§4d/§4g).

All ``k`` partitions grow *concurrently*: every superstep stacks the
fresh candidates of all growing phases into one fused
``hype_score_select`` device call against a graph image (CSR +
assignment + score cache) that was uploaded once. Scores survive across
refills and phases — admissions *decrement* their neighbors' cached
scores instead of wiping the cache. Supersteps run double-buffered on
the shared pipeline driver (``engines.runtime.run_pipeline``);
``pipeline_depth=1`` is the lock-step schedule, bit for bit.

The module co-locates the engine's jitted device programs with its
state: the default ``pipeline_superstep_device`` plus the memory-rung
variants of DESIGN.md §4g (``chunked`` / ``spill`` / ``paged``), all
built from the traced helpers in ``core/scoring.py`` so they stay
semantically identical to each other — and to the sharded engine's
program (``engines.sharded``).
"""
from __future__ import annotations

import dataclasses
import functools as _functools
from typing import Optional

import numpy as np

from ..core.hypergraph import Hypergraph
from ..core.scoring import (_apply_host_injections, _gather_fresh_tiles,
                            _poison_guard, _stale_masked_prev)
from .batched import BatchedParams
from .pipeline import PipelineState, _CallArgs
from .runtime import (BatchedStats, maybe_refine, run_pipeline as
                      _run_pipeline, run_pipeline_budgeted as
                      _run_pipeline_budgeted)


@dataclasses.dataclass
class SuperstepParams(BatchedParams):
    """Knobs for the superstep engine (DESIGN.md §4).

    Inherits the batched knobs; ``t`` (admissions per phase per
    superstep), ``s``, ``pool_cap`` and ``seed`` keep their meaning.
    ``b``/``kernel_min``/``refill_lo`` are unused — refills are sized by
    ``rows`` and every score goes through the fused device call.
    """
    # fresh candidate rows per phase per superstep; None = max(8, t) so
    # refills keep up with the admission drain at any t
    rows: Optional[int] = None
    # in-flight supersteps of the double-buffered pipeline (DESIGN.md
    # §4d). 1 = lock-step (bit-identical to the pre-pipeline engine);
    # 2 = the default overlap: while the device runs superstep N the
    # host mirrors superstep N-1's admissions and packs superstep N+1.
    pipeline_depth: int = 2
    # device-memory budget (core/membudget.py, DESIGN.md §4g): bytes,
    # a "512MB"/"2GiB" string, or None = the REPRO_DEVICE_MEM_BUDGET
    # env var, falling back to the backend's reported allocator limit.
    # The engine plans its tile sizes against the budget before upload
    # and walks the memory-rung ladder on (real or injected) OOM.
    mem_budget: Optional[object] = None


# --------------------------------------------------------------------- #
# Device-resident superstep program: one jitted call performs the whole
# per-superstep device work — apply the host's injection delta (seeds /
# restarts), decrement-invalidate the cached scores of the delta's
# neighbors, gather the fresh candidate tiles from the device CSR, run
# the fused score+select kernel, write the fresh scores back into the
# device cache, and apply the per-phase admissions *on device*: stale
# proposals (candidates assigned by an interleaved superstep of the
# pipeline) are masked out, and the per-phase remaining-target cap is
# enforced against a device-resident admission counter. Winner-neighbor
# decrements ride the NEXT dispatch's host-preaggregated dirty pairs
# (the lock-step schedule). Only ids cross the host boundary, and the
# (n,)-sized assignment/cache (plus the (k,) counter) are *donated* —
# each superstep updates the image in place instead of copying it.


@_functools.lru_cache(maxsize=None)
def _pipeline_program():
    import jax
    import jax.numpy as jnp
    from repro.kernels.hype_score.kernel import SELECT_PAD
    from repro.kernels.hype_score.ops import hype_score_select

    # poison is NOT donated: at pipeline depth > 1 each in-flight handle
    # keeps a reference to its own poison output, which the next
    # dispatch would otherwise consume before harvest can read it —
    # and it is 4 bytes, so donation buys nothing.
    @_functools.partial(
        jax.jit, static_argnames=("tile_l", "select_k", "interpret"),
        donate_argnums=(2, 3, 4))
    def step(indptr, indices, assign, cache, acc, poison, delta_ids,
             delta_vals, dirty_ids, dirty_counts, fresh, bias, pool,
             fringe, targets, reset, *, tile_l, select_k, interpret):
        n = assign.shape[0]
        G, R = fresh.shape
        assign0, cache0, acc0 = assign, cache, acc
        # 1.-2. host injections (seeds / restarts — decrement-exact: the
        #    dirty pairs carry their pre-aggregated neighbor multiset
        #    plus earlier winners' queued decrements); the host only
        #    injects vertices that cannot sit in any in-flight slot, so
        #    the scatter is race-free at any pipeline depth.
        assign, cache, acc = _apply_host_injections(
            assign, cache, acc, delta_ids, delta_vals, dirty_ids,
            dirty_counts)
        # 3. gather fresh candidate tiles from the device CSR
        flat = fresh.reshape(-1)
        tile = _gather_fresh_tiles(indptr, indices, assign, flat, tile_l)
        # 4. held pool scores, stale slots masked (the redraw rule)
        prev, n_stale = _stale_masked_prev(pool, assign, cache)
        # 5. fused score + per-phase top-select
        scores, sel_idx, sel_val = hype_score_select(
            tile.reshape(G, R, tile_l), fringe, bias, prev,
            select_k=select_k, interpret=interpret)
        # 6. fresh scores enter the cache (pad rows dropped)
        cache = cache.at[jnp.where(flat >= 0, flat, n)].set(
            scores.reshape(-1), mode="drop")
        # 7. map selected slots to vertex ids; admissible = a real score
        #    on a still-unassigned id. The per-phase cap is the phase's
        #    remaining target, computed against the *device* totals —
        #    the host view may lag the pipeline, the device never does.
        slots = jnp.concatenate([fresh, pool], axis=1)
        cand = jnp.take_along_axis(slots, sel_idx, axis=1)
        ok = (sel_val < jnp.float32(SELECT_PAD)) & (cand >= 0)
        ok &= assign[jnp.where(cand >= 0, cand, 0)] < 0
        cap = jnp.maximum(targets - acc, 0)
        rank = jnp.cumsum(ok.astype(jnp.int32), axis=1)
        adm = ok & (rank <= cap[:, None])
        winners = jnp.where(adm, cand, -1)
        # 8. apply the winners on device (the host mirrors them at
        #    harvest time, possibly supersteps later). Their score-cache
        #    decrements stay HOST-side: the harvest pre-aggregates the
        #    winners' neighbor multiset into the next dispatch's dirty
        #    pairs — shipping (unique id, count) pairs is far cheaper
        #    than a (G*t, tile_l) gather+scatter here, and at depth 1 it
        #    reproduces the lock-step decrement schedule exactly.
        phase_row = jax.lax.broadcasted_iota(jnp.int32, adm.shape, 0)
        assign = assign.at[jnp.where(adm, cand, n)].set(
            phase_row, mode="drop")
        acc = acc + adm.sum(axis=1, dtype=acc.dtype)
        # 9. NaN/inf quarantine: a poisoned superstep reverts every
        #    mutation and admits nothing; the host replays it from the
        #    handle's buffers (reset=1). A no-op select when clean, so
        #    fault-free runs stay bit-identical.
        poisoned = _poison_guard(flat, scores.reshape(-1), poison, reset)
        assign = jnp.where(poisoned, assign0, assign)
        cache = jnp.where(poisoned, cache0, cache)
        acc = jnp.where(poisoned, acc0, acc)
        winners = jnp.where(poisoned, -1, winners)
        n_stale = jnp.where(poisoned, 0, n_stale)
        poison = poisoned.astype(jnp.int32)[None]
        return assign, cache, acc, poison, winners, n_stale

    return step


def pipeline_superstep_device(indptr, indices, assign, cache, acc,
                              poison, delta_ids, delta_vals, dirty_ids,
                              dirty_counts, fresh, bias, pool, fringe,
                              targets, reset, *, tile_l: int,
                              select_k: int, interpret: bool):
    """Run one device superstep; see ``_pipeline_program`` for the plan.

    All array arguments are device-resident jax arrays except the small
    per-superstep id buffers (delta, dirty, fresh, bias, pool, fringe,
    targets, reset), which are the only host->device traffic.
    ``assign``, ``cache``, ``acc`` and ``poison`` are DONATED — callers
    must keep the returned arrays and never touch the inputs again.
    ``poison`` is the sticky (1,) int32 quarantine flag threaded
    through the run (see ``scoring._poison_guard``); ``reset`` is the
    (1,) int32 replay marker. ``tile_l`` is a static gather width
    (bucketed by the caller so the program retraces only a handful of
    times); ``select_k`` is the per-phase admission count.
    Returns ``(assign', cache', acc', poison', winners, n_stale)``
    where ``winners`` is (G, select_k) int32 admitted ids (-1 = none),
    ``n_stale`` counts pool slots skipped because an interleaved
    superstep of the pipeline had already assigned them, and
    ``poison'[0] > 0`` means the superstep aborted (nothing applied)
    and must be replayed by the host.
    """
    return _pipeline_program()(
        indptr, indices, assign, cache, acc, poison, delta_ids,
        delta_vals, dirty_ids, dirty_counts, fresh, bias, pool, fringe,
        targets, reset, tile_l=tile_l, select_k=select_k,
        interpret=interpret)


# ------------------------------------------------- memory-rung variants
# Program variants for the memory-budget rung ladder (core/membudget.py,
# DESIGN.md §4g). Each shares the traced helpers of ``core/scoring.py``
# with ``_pipeline_program`` — the default program is deliberately left
# untouched (its depth-1 outputs are golden-hashed), and every variant
# is bit-exact to it on the single-device engine:
#
#   * ``_chunked_program``   — scores the G phases in ``g_chunk``
#     sequential slices (``lax.map``), dividing the peak (G·R, tile_l)
#     gather-tile footprint by ``g_chunk``. Phases are independent
#     until admission (selection runs against the pre-winner assignment
#     snapshot), so chunked scoring computes the same scores in the
#     same order.
#   * ``_spill_program``     — no device score cache: the host keeps a
#     float32 mirror, applies the dirty decrements itself (IEEE-
#     identical float32 adds of integer counts) and ships the held-pool
#     scores in; fresh scores return with the winners. Depth-1 only.
#   * ``_paged_program``     — takes the *pre-gathered raw* neighbor
#     tile (built chunk-by-chunk by ``membudget.PagedAdjacency``) and
#     applies the assignment masking in-program, reproducing
#     ``_gather_fresh_tiles``'s output exactly without a resident CSR.


@_functools.lru_cache(maxsize=None)
def _chunked_program():
    import jax
    import jax.numpy as jnp
    from repro.kernels.hype_score.kernel import SELECT_PAD
    from repro.kernels.hype_score.ops import hype_score_select

    @_functools.partial(
        jax.jit,
        static_argnames=("tile_l", "select_k", "interpret", "g_chunk"),
        donate_argnums=(2, 3, 4))
    def step(indptr, indices, assign, cache, acc, poison, delta_ids,
             delta_vals, dirty_ids, dirty_counts, fresh, bias, pool,
             fringe, targets, reset, *, tile_l, select_k, interpret,
             g_chunk):
        n = assign.shape[0]
        G, R = fresh.shape
        assign0, cache0, acc0 = assign, cache, acc
        assign, cache, acc = _apply_host_injections(
            assign, cache, acc, delta_ids, delta_vals, dirty_ids,
            dirty_counts)
        prev, n_stale = _stale_masked_prev(pool, assign, cache)
        # phase-chunked gather + score: pad G to a g_chunk multiple
        # (pad phases carry -1 candidates / +inf bias, so they select
        # nothing), then lax.map the gather + fused kernel over the
        # chunks — sequential execution divides the peak tile bytes by
        # g_chunk while computing the exact scores of the full call.
        Gc = -(-G // g_chunk)
        pad = g_chunk * Gc - G

        def padg(a, fill):
            if pad == 0:
                return a
            return jnp.concatenate(
                [a, jnp.full((pad,) + a.shape[1:], fill, a.dtype)])

        fresh_p = padg(fresh, -1).reshape(g_chunk, Gc, R)
        bias_p = padg(bias, jnp.inf).reshape(g_chunk, Gc, R)
        prev_p = padg(prev, jnp.inf).reshape(g_chunk, Gc, prev.shape[1])
        fringe_p = padg(fringe, -1).reshape(
            g_chunk, Gc, fringe.shape[1])

        def score_chunk(args):
            fr_c, bi_c, pr_c, fg_c = args
            flat_c = fr_c.reshape(-1)
            tile_c = _gather_fresh_tiles(indptr, indices, assign,
                                         flat_c, tile_l)
            return hype_score_select(
                tile_c.reshape(Gc, R, tile_l), fg_c, bi_c, pr_c,
                select_k=select_k, interpret=interpret)

        scores_c, sel_idx_c, sel_val_c = jax.lax.map(
            score_chunk, (fresh_p, bias_p, prev_p, fringe_p))
        scores = scores_c.reshape(g_chunk * Gc, R)[:G]
        sel_idx = sel_idx_c.reshape(g_chunk * Gc, select_k)[:G]
        sel_val = sel_val_c.reshape(g_chunk * Gc, select_k)[:G]
        # steps 6-9 of _pipeline_program, verbatim
        flat = fresh.reshape(-1)
        cache = cache.at[jnp.where(flat >= 0, flat, n)].set(
            scores.reshape(-1), mode="drop")
        slots = jnp.concatenate([fresh, pool], axis=1)
        cand = jnp.take_along_axis(slots, sel_idx, axis=1)
        ok = (sel_val < jnp.float32(SELECT_PAD)) & (cand >= 0)
        ok &= assign[jnp.where(cand >= 0, cand, 0)] < 0
        cap = jnp.maximum(targets - acc, 0)
        rank = jnp.cumsum(ok.astype(jnp.int32), axis=1)
        adm = ok & (rank <= cap[:, None])
        winners = jnp.where(adm, cand, -1)
        phase_row = jax.lax.broadcasted_iota(jnp.int32, adm.shape, 0)
        assign = assign.at[jnp.where(adm, cand, n)].set(
            phase_row, mode="drop")
        acc = acc + adm.sum(axis=1, dtype=acc.dtype)
        poisoned = _poison_guard(flat, scores.reshape(-1), poison, reset)
        assign = jnp.where(poisoned, assign0, assign)
        cache = jnp.where(poisoned, cache0, cache)
        acc = jnp.where(poisoned, acc0, acc)
        winners = jnp.where(poisoned, -1, winners)
        n_stale = jnp.where(poisoned, 0, n_stale)
        poison = poisoned.astype(jnp.int32)[None]
        return assign, cache, acc, poison, winners, n_stale

    return step


def chunked_superstep_device(indptr, indices, assign, cache, acc,
                             poison, delta_ids, delta_vals, dirty_ids,
                             dirty_counts, fresh, bias, pool, fringe,
                             targets, reset, *, tile_l: int,
                             select_k: int, interpret: bool,
                             g_chunk: int):
    """``pipeline_superstep_device`` with phase-chunked scoring.

    Identical contract and bit-identical outputs; ``g_chunk`` slices
    the gather + fused-kernel stage so only 1/g_chunk of the phases'
    tiles is materialized at a time (memory rung 1+, DESIGN.md §4g).
    """
    return _chunked_program()(
        indptr, indices, assign, cache, acc, poison, delta_ids,
        delta_vals, dirty_ids, dirty_counts, fresh, bias, pool, fringe,
        targets, reset, tile_l=tile_l, select_k=select_k,
        interpret=interpret, g_chunk=g_chunk)


@_functools.lru_cache(maxsize=None)
def _spill_program():
    import jax
    import jax.numpy as jnp
    from repro.kernels.hype_score.kernel import SELECT_PAD
    from repro.kernels.hype_score.ops import hype_score_select

    @_functools.partial(
        jax.jit, static_argnames=("tile_l", "select_k", "interpret"),
        donate_argnums=(2, 3))
    def step(indptr, indices, assign, acc, poison, delta_ids,
             delta_vals, fresh, bias, pool, prev_host, fringe, targets,
             reset, *, tile_l, select_k, interpret):
        n = assign.shape[0]
        G, R = fresh.shape
        assign0, acc0 = assign, acc
        # injections only — the dirty decrements were applied to the
        # HOST cache mirror at pack time (identical float32 arithmetic)
        inj = delta_ids >= 0
        assign = assign.at[jnp.where(inj, delta_ids, n)].set(
            delta_vals, mode="drop")
        acc = acc.at[jnp.where(inj, delta_vals, acc.shape[0])].add(
            1, mode="drop")
        flat = fresh.reshape(-1)
        tile = _gather_fresh_tiles(indptr, indices, assign, flat, tile_l)
        # held pool scores arrive from the host mirror; staleness is
        # still masked on device against the post-injection assignment
        psafe = jnp.where(pool >= 0, pool, 0)
        pool_ok = (pool >= 0) & (assign[psafe] < 0)
        prev = jnp.where(pool_ok, prev_host, jnp.inf).astype(jnp.float32)
        n_stale = ((pool >= 0) & ~pool_ok).sum().astype(jnp.int32)
        scores, sel_idx, sel_val = hype_score_select(
            tile.reshape(G, R, tile_l), fringe, bias, prev,
            select_k=select_k, interpret=interpret)
        slots = jnp.concatenate([fresh, pool], axis=1)
        cand = jnp.take_along_axis(slots, sel_idx, axis=1)
        ok = (sel_val < jnp.float32(SELECT_PAD)) & (cand >= 0)
        ok &= assign[jnp.where(cand >= 0, cand, 0)] < 0
        cap = jnp.maximum(targets - acc, 0)
        rank = jnp.cumsum(ok.astype(jnp.int32), axis=1)
        adm = ok & (rank <= cap[:, None])
        winners = jnp.where(adm, cand, -1)
        phase_row = jax.lax.broadcasted_iota(jnp.int32, adm.shape, 0)
        assign = assign.at[jnp.where(adm, cand, n)].set(
            phase_row, mode="drop")
        acc = acc + adm.sum(axis=1, dtype=acc.dtype)
        poisoned = _poison_guard(flat, scores.reshape(-1), poison, reset)
        assign = jnp.where(poisoned, assign0, assign)
        acc = jnp.where(poisoned, acc0, acc)
        winners = jnp.where(poisoned, -1, winners)
        n_stale = jnp.where(poisoned, 0, n_stale)
        poison = poisoned.astype(jnp.int32)[None]
        # fresh scores return to the host, which owns the cache now;
        # the host only writes them after the poison check
        return assign, acc, poison, winners, n_stale, scores

    return step


def spill_superstep_device(indptr, indices, assign, acc, poison,
                           delta_ids, delta_vals, fresh, bias, pool,
                           prev_host, fringe, targets, reset, *,
                           tile_l: int, select_k: int, interpret: bool):
    """``pipeline_superstep_device`` with the score cache spilled to host.

    The (n,) float32 cache lives on host (memory rung 4, depth-1 only):
    the caller applies dirty decrements to its mirror, ships the held
    pool's ``prev_host`` scores in, and writes the returned ``scores``
    back at harvest. All arithmetic the device skipped is IEEE-exact
    float32 on host, so results match the resident-cache program bit
    for bit at depth 1. ``assign``/``acc`` are DONATED.
    Returns ``(assign', acc', poison', winners, n_stale, scores)``.
    """
    return _spill_program()(
        indptr, indices, assign, acc, poison, delta_ids, delta_vals,
        fresh, bias, pool, prev_host, fringe, targets, reset,
        tile_l=tile_l, select_k=select_k, interpret=interpret)


@_functools.lru_cache(maxsize=None)
def _paged_program():
    import jax
    import jax.numpy as jnp
    from repro.kernels.hype_score.kernel import SELECT_PAD
    from repro.kernels.hype_score.ops import hype_score_select

    @_functools.partial(
        jax.jit, static_argnames=("select_k", "interpret"),
        donate_argnums=(0, 1, 2))
    def step(assign, cache, acc, poison, delta_ids, delta_vals,
             dirty_ids, dirty_counts, tile_raw, fresh, bias, pool,
             fringe, targets, reset, *, select_k, interpret):
        n = assign.shape[0]
        G, R = fresh.shape
        tile_l = tile_raw.shape[1]
        assign0, cache0, acc0 = assign, cache, acc
        assign, cache, acc = _apply_host_injections(
            assign, cache, acc, delta_ids, delta_vals, dirty_ids,
            dirty_counts)
        flat = fresh.reshape(-1)
        # the raw tile was gathered from the paged CSR before this call;
        # masking assigned neighbors here — against the post-injection
        # assignment — reproduces _gather_fresh_tiles's output exactly
        valid = tile_raw >= 0
        unassigned = assign[jnp.where(valid, tile_raw, 0)] < 0
        tile = jnp.where(valid & unassigned, tile_raw,
                         -1).astype(jnp.int32)
        prev, n_stale = _stale_masked_prev(pool, assign, cache)
        scores, sel_idx, sel_val = hype_score_select(
            tile.reshape(G, R, tile_l), fringe, bias, prev,
            select_k=select_k, interpret=interpret)
        cache = cache.at[jnp.where(flat >= 0, flat, n)].set(
            scores.reshape(-1), mode="drop")
        slots = jnp.concatenate([fresh, pool], axis=1)
        cand = jnp.take_along_axis(slots, sel_idx, axis=1)
        ok = (sel_val < jnp.float32(SELECT_PAD)) & (cand >= 0)
        ok &= assign[jnp.where(cand >= 0, cand, 0)] < 0
        cap = jnp.maximum(targets - acc, 0)
        rank = jnp.cumsum(ok.astype(jnp.int32), axis=1)
        adm = ok & (rank <= cap[:, None])
        winners = jnp.where(adm, cand, -1)
        phase_row = jax.lax.broadcasted_iota(jnp.int32, adm.shape, 0)
        assign = assign.at[jnp.where(adm, cand, n)].set(
            phase_row, mode="drop")
        acc = acc + adm.sum(axis=1, dtype=acc.dtype)
        poisoned = _poison_guard(flat, scores.reshape(-1), poison, reset)
        assign = jnp.where(poisoned, assign0, assign)
        cache = jnp.where(poisoned, cache0, cache)
        acc = jnp.where(poisoned, acc0, acc)
        winners = jnp.where(poisoned, -1, winners)
        n_stale = jnp.where(poisoned, 0, n_stale)
        poison = poisoned.astype(jnp.int32)[None]
        return assign, cache, acc, poison, winners, n_stale

    return step


def paged_superstep_device(assign, cache, acc, poison, delta_ids,
                           delta_vals, dirty_ids, dirty_counts,
                           tile_raw, fresh, bias, pool, fringe, targets,
                           reset, *, select_k: int, interpret: bool):
    """``pipeline_superstep_device`` without a resident CSR image.

    ``tile_raw`` is the (G·R, tile_l) *unmasked* neighbor-id tile
    assembled by ``membudget.PagedAdjacency.gather`` (memory rung 5);
    the program applies the assignment masking itself, so the scores —
    and therefore the whole run — are bit-identical to the
    resident-image engine. The single-device program's only other CSR
    use (winner decrements) already lives host-side, which is what
    makes this rung possible at all. ``assign``/``cache``/``acc`` are
    DONATED. Returns ``(assign', cache', acc', poison', winners,
    n_stale)``.
    """
    return _paged_program()(
        assign, cache, acc, poison, delta_ids, delta_vals, dirty_ids,
        dirty_counts, tile_raw, fresh, bias, pool, fringe, targets,
        reset, select_k=select_k, interpret=interpret)


# --------------------------------------------------------------------- #
class SuperstepState(PipelineState):
    """Pipeline state wired to this module's single-device programs."""

    def _call_program(self, args: _CallArgs, reset: np.ndarray):
        """Issue the fused superstep program; rotate the donated image.

        Returns ``(winners, n_stale, ncf, scores)`` futures (``ncf`` is
        None for the single-device engine; ``scores`` is None except on
        the spill rung, where the host owns the score cache and the
        fresh scores ride back with the winners). The memory plan picks
        the program variant (DESIGN.md §4g) — all of them bit-exact to
        the default on this engine.
        """
        if self.paged_adj is not None:
            tile_raw = self.paged_adj.gather(
                args.fresh.reshape(-1), self.tile_l)
            (self.dev_assign, self.dev_cache, self.dev_acc,
             self.dev_poison, winners, n_stale) = \
                paged_superstep_device(
                    self.dev_assign, self.dev_cache, self.dev_acc,
                    self.dev_poison, args.delta, args.vals, args.dirty,
                    args.dcnt, tile_raw, args.fresh, args.bias,
                    args.pool_arr, args.fringe, args.targets, reset,
                    select_k=args.select_k, interpret=self.interpret)
            return winners, n_stale, None, None
        if self.host_cache is not None:
            (self.dev_assign, self.dev_acc, self.dev_poison, winners,
             n_stale, scores) = spill_superstep_device(
                self.dev[0], self.dev[1], self.dev_assign, self.dev_acc,
                self.dev_poison, args.delta, args.vals, args.fresh,
                args.bias, args.pool_arr, args.prev, args.fringe,
                args.targets, reset, tile_l=self.tile_l,
                select_k=args.select_k, interpret=self.interpret)
            return winners, n_stale, None, scores
        if self.g_chunk > 1:
            (self.dev_assign, self.dev_cache, self.dev_acc,
             self.dev_poison, winners, n_stale) = \
                chunked_superstep_device(
                    self.dev[0], self.dev[1], self.dev_assign,
                    self.dev_cache, self.dev_acc, self.dev_poison,
                    args.delta, args.vals, args.dirty, args.dcnt,
                    args.fresh, args.bias, args.pool_arr, args.fringe,
                    args.targets, reset, tile_l=self.tile_l,
                    select_k=args.select_k, interpret=self.interpret,
                    g_chunk=self.g_chunk)
            return winners, n_stale, None, None
        (self.dev_assign, self.dev_cache, self.dev_acc, self.dev_poison,
         winners, n_stale) = pipeline_superstep_device(
            self.dev[0], self.dev[1], self.dev_assign, self.dev_cache,
            self.dev_acc, self.dev_poison, args.delta, args.vals,
            args.dirty, args.dcnt, args.fresh, args.bias, args.pool_arr,
            args.fringe, args.targets, reset, tile_l=self.tile_l,
            select_k=args.select_k, interpret=self.interpret)
        return winners, n_stale, None, None


def run_pipeline(hg: Hypergraph, k: int, p: SuperstepParams,
                 mem_rung: int = 0,
                 mem_warm: Optional[np.ndarray] = None,
                 mem_retries: int = 0):
    """One superstep-engine pipeline run (no memory-rung retry loop).

    ``engines.runtime.run_pipeline`` with this engine's state factory.
    Exposed for callers that drive the rung ladder themselves (the
    device engine's OOM fallback, the membudget test harness).
    """
    return _run_pipeline(
        hg, k, p,
        lambda p2, rung: SuperstepState(hg, k, p2, mem_rung=rung),
        "hype_superstep", devices=0, mem_rung=mem_rung,
        mem_warm=mem_warm, mem_retries=mem_retries)


def run_pipeline_budgeted(hg: Hypergraph, k: int, p: SuperstepParams):
    """``run_pipeline`` under the §4g memory-rung retry loop."""
    return _run_pipeline_budgeted(
        hg, k, p,
        lambda p2, rung: SuperstepState(hg, k, p2, mem_rung=rung),
        "hype_superstep", devices=0)


def hype_superstep_partition(hg: Hypergraph, k: int,
                             params: Optional[SuperstepParams] = None,
                             return_stats: bool = False):
    """Partition ``hg`` with the device-resident superstep engine.

    Same contract as ``hype_batched_partition`` (complete int32
    assignment, max - min <= 1 vertex balance) but all ``k`` partitions
    grow *concurrently*: every superstep stacks the fresh candidates of
    all growing phases into one fused ``hype_score_select`` device call
    against a graph image (CSR + assignment + score cache) that was
    uploaded once. Scores survive across refills and phases — admissions
    *decrement* their neighbors' cached scores instead of wiping the
    cache. ``params.pipeline_depth`` supersteps run double-buffered
    (DESIGN.md §4d): while the device computes superstep N the host
    mirrors N-1's admissions and packs N+1; ``pipeline_depth=1`` is the
    lock-step schedule, bit for bit. Falls back to
    ``hype_batched_partition`` when the adjacency guard trips
    (pathological hub expansion).
    """
    if params is None:
        params = SuperstepParams()
    if params.rows is None:
        params = dataclasses.replace(params, rows=max(8, params.t))
    if k < 1:
        raise ValueError("k must be >= 1")
    if params.t < 1 or params.rows < 1 or params.pool_cap < 1:
        raise ValueError("rows, pool_cap, t must all be >= 1")
    if params.pipeline_depth < 1:
        raise ValueError("pipeline_depth must be >= 1")
    if params.snapshot_every > 0 and not params.snapshot_dir:
        raise ValueError("snapshot_every requires snapshot_dir")
    if k == 1:
        out = np.zeros(hg.n, dtype=np.int32)
        return (out, BatchedStats()) if return_stats else out
    assignment, st = run_pipeline_budgeted(hg, k, params)
    if assignment is None:
        from .batched import hype_batched_partition
        return hype_batched_partition(hg, k, params, return_stats)
    assert (assignment >= 0).all()
    assignment = maybe_refine(hg, k, params, assignment, st.stats)
    if return_stats:
        return assignment, st.stats
    return assignment


__all__ = ["SuperstepParams", "SuperstepState",
           "hype_superstep_partition", "run_pipeline",
           "run_pipeline_budgeted", "pipeline_superstep_device",
           "chunked_superstep_device", "spill_superstep_device",
           "paged_superstep_device"]
