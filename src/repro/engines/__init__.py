"""The HYPE fast-engine family (DESIGN.md §1).

One module per engine, co-located with its device program and Params
dataclass, on a shared runtime:

  * ``runtime``   — ``EngineRuntime``, ``BatchedStats``, the pipeline
    driver + memory-rung retry loop, snapshot/restore, ``maybe_refine``
  * ``pipeline``  — ``PipelineState``, the shared host half of the
    double-buffered superstep pipeline (abstract device call)
  * ``batched``   — host tiles + Pallas scoring kernel (``hype_batched``)
  * ``superstep`` — device-resident superstep engine (``hype_superstep``)
  * ``sharded``   — mesh-sharded superstep engine (``hype_sharded``)
  * ``device``    — fully device-resident loop engine (``hype_device``)

Layering (enforced by ``tools/check_layering.py``): engine modules may
import ``runtime``/``pipeline``, ``repro.core.*`` and ``repro.kernels.*``
freely, and only *public* names from sibling engine modules (the Params
inheritance chain and the fallback entry points); ``repro.core`` never
imports this package at module level.

The engine modules import lazily here — ``import repro.engines`` stays
cheap; jax is only pulled in when an engine is actually used.
"""
from __future__ import annotations

_EXPORTS = {
    "BatchedStats": "runtime",
    "EngineRuntime": "runtime",
    "maybe_refine": "runtime",
    "PipelineState": "pipeline",
    "BatchedParams": "batched",
    "BatchedState": "batched",
    "hype_batched_partition": "batched",
    "SuperstepParams": "superstep",
    "SuperstepState": "superstep",
    "hype_superstep_partition": "superstep",
    "ShardedParams": "sharded",
    "ShardedState": "sharded",
    "hype_sharded_partition": "sharded",
    "DeviceParams": "device",
    "hype_device_partition": "device",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name: str):
    mod = _EXPORTS.get(name)
    if mod is None:
        raise AttributeError(
            f"module {__name__!r} has no attribute {name!r}")
    import importlib
    return getattr(importlib.import_module(f".{mod}", __name__), name)
