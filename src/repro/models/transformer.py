"""Decoder-only transformer LM: GQA, RoPE, qk-norm, sliding-window, MoE.

Covers the five assigned LM architectures (stablelm-3b, qwen3-8b,
llama3-405b, mixtral-8x22b, granite-moe-3b-a800m). Layers are scanned
(stacked params) so the HLO stays small at 126 layers, with optional remat.

Sharding is injected through ``cfg.constrain(x, logical_axes)`` — a no-op
by default; the launcher installs mesh-aware rules (see repro/dist).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from .common import (DEFAULT_DTYPE, apply_rope, dense_init, embed_init,
                     rms_norm, rotary_embedding, softmax_cross_entropy)
from .moe import MoEConfig, init_moe_layer, moe_ffn


def _noop_constrain(x, axes):
    return x


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    qk_norm: bool = False
    window: Optional[int] = None          # sliding-window attention (Mixtral)
    rope_theta: float = 10_000.0
    moe: Optional[MoEConfig] = None
    dtype: object = DEFAULT_DTYPE
    remat: bool = True
    scan_layers: bool = True
    # Megatron-style sequence parallelism: residuals (the tensors remat
    # saves) are sharded over the model axis along seq; XLA inserts the
    # all-gather/all-to-all transitions at attention/MLP entry.
    seq_shard: bool = False
    # "einsum": materialize (S, S) scores. "blockwise": online-softmax
    # over K tiles (Rabe-Staats / flash-attention dataflow in pure XLA) —
    # the jnp analogue of kernels/flash_attention for machines where the
    # Pallas kernel can't lower. Unrolled python loop so HLO cost analysis
    # counts every tile.
    attention_impl: str = "einsum"
    attention_block: int = 1024
    # paged-style decode: the KV cache is a read-only input (no
    # dynamic-update-slice on a sharded dim — the #1 decode collective
    # pathology, see EXPERIMENTS.md §Perf); the new token's K/V are
    # returned separately for the host/outer loop to append block-wise.
    decode_paged: bool = False
    # pad embedding/lm_head rows to a multiple of 256 so the vocab dim
    # always shards over the model axis (non-divisible vocabs otherwise
    # fall back to a d-sharded head = full-logits all-reduce; §Perf D).
    # Padded logit columns are masked to -inf before the softmax.
    pad_vocab: bool = False
    # accumulate MoE expert GEMMs in bf16 so GSPMD's backward partial-sum
    # all-reduces move bf16 instead of fp32 (halves MoE backward wire at
    # a numerical-precision trade-off; §Perf D).
    moe_accum_bf16: bool = False
    moe_cf_override: Optional[float] = None
    # shard the expert-capacity dim of the dispatch buffers over the model
    # axis (weights replicated — tiny for fine-grained MoE) so expert
    # GEMMs have no sharded contraction at all (§Perf D4).
    moe_shard_c: bool = False

    @property
    def vocab_padded(self) -> int:
        if self.pad_vocab:
            return ((self.vocab + 255) // 256) * 256
        return self.vocab
    # logical-axis constraint hook, installed by the launcher
    constrain: Callable = _noop_constrain

    @property
    def res_axis(self) -> str:
        return "res_seq" if self.seq_shard else "seq"

    @property
    def params_dense(self) -> int:
        """Total parameter count N (for MODEL_FLOPS = 6*N*D)."""
        a = self.d_model * (self.n_heads + 2 * self.n_kv_heads) * self.d_head
        a += self.n_heads * self.d_head * self.d_model
        if self.moe is None:
            f = 3 * self.d_model * self.d_ff
        else:
            f = 3 * self.d_model * self.d_ff * self.moe.n_experts \
                + self.d_model * self.moe.n_experts
        per_layer = a + f + 2 * self.d_model
        return (self.n_layers * per_layer + 2 * self.vocab * self.d_model
                + self.d_model)

    @property
    def params_active(self) -> int:
        """Active parameters per token (MoE: top_k of n_experts)."""
        if self.moe is None:
            return self.params_dense
        a = self.d_model * (self.n_heads + 2 * self.n_kv_heads) * self.d_head
        a += self.n_heads * self.d_head * self.d_model
        f = 3 * self.d_model * self.d_ff * self.moe.top_k \
            + self.d_model * self.moe.n_experts
        per_layer = a + f + 2 * self.d_model
        return (self.n_layers * per_layer + 2 * self.vocab * self.d_model
                + self.d_model)


# ------------------------------------------------------------------ params

def init_layer_params(key, cfg: TransformerConfig):
    ks = jax.random.split(key, 8)
    d, dh = cfg.d_model, cfg.d_head
    p = {
        "ln1": jnp.ones((d,), cfg.dtype),
        "ln2": jnp.ones((d,), cfg.dtype),
        "wq": dense_init(ks[0], d, cfg.n_heads * dh, cfg.dtype),
        "wk": dense_init(ks[1], d, cfg.n_kv_heads * dh, cfg.dtype),
        "wv": dense_init(ks[2], d, cfg.n_kv_heads * dh, cfg.dtype),
        "wo": dense_init(ks[3], cfg.n_heads * dh, d, cfg.dtype),
    }
    if cfg.qk_norm:
        p["qnorm"] = jnp.ones((dh,), cfg.dtype)
        p["knorm"] = jnp.ones((dh,), cfg.dtype)
    if cfg.moe is None:
        p["w_gate"] = dense_init(ks[4], d, cfg.d_ff, cfg.dtype)
        p["w_up"] = dense_init(ks[5], d, cfg.d_ff, cfg.dtype)
        p["w_down"] = dense_init(ks[6], cfg.d_ff, d, cfg.dtype)
    else:
        p.update(init_moe_layer(ks[7], d, cfg.d_ff, cfg.moe, cfg.dtype))
    return p


def init_params(key, cfg: TransformerConfig):
    k_emb, k_head, k_layers = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    if cfg.scan_layers:
        layers = jax.vmap(lambda k: init_layer_params(k, cfg))(layer_keys)
    else:
        layers = [init_layer_params(k, cfg) for k in layer_keys]
    return {
        "embed": embed_init(k_emb, cfg.vocab_padded, cfg.d_model, cfg.dtype),
        "lm_head": dense_init(k_head, cfg.d_model, cfg.vocab_padded,
                              cfg.dtype),
        "ln_f": jnp.ones((cfg.d_model,), cfg.dtype),
        "layers": layers,
    }


def abstract_params(cfg: TransformerConfig):
    """ShapeDtypeStruct pytree of params — no allocation (for the dry-run)."""
    return jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))


# ----------------------------------------------------------------- attention

def _attention(cfg: TransformerConfig, lp, x, sin, cos, mask):
    """Full (optionally windowed) training/prefill attention.

    Returns (output, (k, v)) so prefill can collect the cache without
    recomputing projections.
    """
    B, S, d = x.shape
    Hq, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    G = Hq // Hkv
    q = (x @ lp["wq"]).reshape(B, S, Hq, Dh)
    k = (x @ lp["wk"]).reshape(B, S, Hkv, Dh)
    v = (x @ lp["wv"]).reshape(B, S, Hkv, Dh)
    if cfg.qk_norm:
        q = rms_norm(q, lp["qnorm"])
        k = rms_norm(k, lp["knorm"])
    q = apply_rope(q, sin, cos)
    k = apply_rope(k, sin, cos)
    q = cfg.constrain(q, ("batch", "seq", "heads", None))
    k = cfg.constrain(k, ("batch", "seq", "kv_heads", None))
    q = q.reshape(B, S, Hkv, G, Dh)
    if cfg.attention_impl == "blockwise" and S > cfg.attention_block:
        out = _blockwise_attention(cfg, q, k, v, mask)
    else:
        scores = jnp.einsum("bshgd,bthd->bhgst", q, k) \
            / jnp.sqrt(Dh).astype(x.dtype)
        scores = jnp.where(mask[None, None, None],
                           scores.astype(jnp.float32), -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        out = jnp.einsum("bhgst,bthd->bshgd", probs, v)
    out = out.reshape(B, S, Hq * Dh)
    return out @ lp["wo"], (k, v)


def _blockwise_attention(cfg, q, k, v, mask):
    """Online-softmax attention over K tiles; never materializes (S, S).

    q: (B, S, Hkv, G, D); k/v: (B, S, Hkv, D); mask: (S, S) bool.
    Python-unrolled over tiles (see TransformerConfig.attention_impl).
    """
    B, S, Hkv, G, Dh = q.shape
    blk = cfg.attention_block
    scale = 1.0 / jnp.sqrt(Dh).astype(jnp.float32)
    m = jnp.full((B, Hkv, G, S, 1), -1e30, jnp.float32)
    l = jnp.zeros((B, Hkv, G, S, 1), jnp.float32)
    acc = jnp.zeros((B, Hkv, G, S, Dh), jnp.float32)
    q32 = q.astype(jnp.float32)
    for t0 in range(0, S, blk):
        kt = k[:, t0:t0 + blk].astype(jnp.float32)
        vt = v[:, t0:t0 + blk].astype(jnp.float32)
        s = jnp.einsum("bshgd,bthd->bhgst", q32, kt) * scale
        s = jnp.where(mask[None, None, None, :, t0:t0 + blk], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * alpha + jnp.einsum("bhgst,bthd->bhgsd", p, vt)
        m = m_new
    out = acc / jnp.maximum(l, 1e-30)
    # (B, Hkv, G, S, D) -> (B, S, Hkv, G, D)
    return out.transpose(0, 3, 1, 2, 4).astype(q.dtype)


def _causal_mask(S: int, window: Optional[int]):
    i = jnp.arange(S)[:, None]
    j = jnp.arange(S)[None, :]
    m = j <= i
    if window is not None:
        m &= j > i - window
    return m


def _dense_ffn(cfg, lp, x):
    h = jax.nn.silu(x @ lp["w_gate"]) * (x @ lp["w_up"])
    h = cfg.constrain(h, ("batch", "seq", "mlp"))
    return h @ lp["w_down"]


def _layer_fwd(cfg: TransformerConfig, lp, x, sin, cos, mask):
    x = cfg.constrain(x, ("batch", cfg.res_axis, None))
    a, kv = _attention(cfg, lp, rms_norm(x, lp["ln1"]), sin, cos, mask)
    x = x + a
    h = rms_norm(x, lp["ln2"])
    if cfg.moe is None:
        f = _dense_ffn(cfg, lp, h)
        aux = jnp.float32(0)
    else:
        f, aux = moe_ffn(cfg, lp, h)
    return x + f, aux, kv


# ------------------------------------------------------------------ forward

def forward(params, tokens, cfg: TransformerConfig):
    """tokens (B, S) -> final hidden states (B, S, d), aux loss."""
    B, S = tokens.shape
    x = params["embed"][tokens].astype(cfg.dtype)
    x = cfg.constrain(x, ("batch", cfg.res_axis, None))
    positions = jnp.arange(S)[None, :]
    sin, cos = rotary_embedding(positions, cfg.d_head, cfg.rope_theta)
    mask = _causal_mask(S, cfg.window)

    if cfg.scan_layers:
        def body(carry, lp):
            x, aux = carry
            x, a, _ = _layer_fwd(cfg, lp, x, sin, cos, mask)
            return (x, aux + a), None
        body_fn = jax.checkpoint(body) if cfg.remat else body
        (x, aux), _ = jax.lax.scan(body_fn, (x, jnp.float32(0)),
                                   params["layers"])
    else:
        aux = jnp.float32(0)
        for lp in params["layers"]:
            f = (jax.checkpoint(partial(_layer_fwd, cfg)) if cfg.remat
                 else partial(_layer_fwd, cfg))
            x, a, _ = f(lp, x, sin, cos, mask)
            aux = aux + a
    return rms_norm(x, params["ln_f"]), aux


def lm_loss(params, batch, cfg: TransformerConfig):
    """batch: {tokens (B,S), labels (B,S)}; returns scalar fp32 loss."""
    x, aux = forward(params, batch["tokens"], cfg)
    logits = x @ params["lm_head"]
    logits = cfg.constrain(logits, ("batch", "seq", "vocab"))
    if cfg.vocab_padded != cfg.vocab:
        pad_mask = jnp.arange(cfg.vocab_padded) >= cfg.vocab
        logits = jnp.where(pad_mask, jnp.float32(-1e30).astype(logits.dtype),
                           logits)
    ce = softmax_cross_entropy(logits, batch["labels"])
    return ce + 0.01 * aux


# ------------------------------------------------------------------ serving

def init_cache(cfg: TransformerConfig, batch: int, max_len: int):
    """KV cache. Sliding-window archs use a rolling buffer of size window."""
    Skv = min(max_len, cfg.window) if cfg.window else max_len
    shape = (cfg.n_layers, batch, Skv, cfg.n_kv_heads, cfg.d_head)
    return {
        "k": jnp.zeros(shape, cfg.dtype),
        "v": jnp.zeros(shape, cfg.dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def abstract_cache(cfg: TransformerConfig, batch: int, max_len: int):
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_len))


def prefill(params, tokens, cfg: TransformerConfig):
    """Run the full prompt, return (cache, last-token logits).

    The cache is produced from the per-layer K/V of the forward pass; for
    windowed attention only the last ``window`` positions are kept.
    """
    B, S = tokens.shape
    x = params["embed"][tokens].astype(cfg.dtype)
    positions = jnp.arange(S)[None, :]
    sin, cos = rotary_embedding(positions, cfg.d_head, cfg.rope_theta)
    mask = _causal_mask(S, cfg.window)
    Skv = min(S, cfg.window) if cfg.window else S

    def body(carry, lp):
        x, aux = carry
        x, a, (k, v) = _layer_fwd(cfg, lp, x, sin, cos, mask)
        return (x, aux + a), (k[:, -Skv:], v[:, -Skv:])

    if cfg.scan_layers:
        body_fn = jax.checkpoint(body) if cfg.remat else body
        (x, _), (ks, vs) = jax.lax.scan(body_fn, (x, jnp.float32(0)),
                                        params["layers"])
    else:
        carry = (x, jnp.float32(0))
        kvs = []
        for lp in params["layers"]:
            carry, kv = body(carry, lp)
            kvs.append(kv)
        x = carry[0]
        ks = jnp.stack([kv[0] for kv in kvs])
        vs = jnp.stack([kv[1] for kv in kvs])
    x = rms_norm(x, params["ln_f"])
    logits = x[:, -1] @ params["lm_head"]
    cache = {"k": ks, "v": vs, "pos": jnp.int32(S)}
    return cache, logits


def _decode_attention(cfg, lp, x, cache_k, cache_v, pos):
    """One-token attention against the cache. x: (B, 1, d)."""
    B = x.shape[0]
    Hq, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    G = Hq // Hkv
    Skv = cache_k.shape[1]
    q = (x @ lp["wq"]).reshape(B, 1, Hq, Dh)
    k = (x @ lp["wk"]).reshape(B, 1, Hkv, Dh)
    v = (x @ lp["wv"]).reshape(B, 1, Hkv, Dh)
    if cfg.qk_norm:
        q = rms_norm(q, lp["qnorm"])
        k = rms_norm(k, lp["knorm"])
    sin, cos = rotary_embedding(pos[None, None], cfg.d_head, cfg.rope_theta)
    q = apply_rope(q, sin, cos)
    k = apply_rope(k, sin, cos)
    # rolling-buffer write position (no-op modulo for full caches)
    slot = pos % Skv
    cache_k = jax.lax.dynamic_update_slice(
        cache_k, k, (0, slot, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(
        cache_v, v, (0, slot, 0, 0))
    cache_k = cfg.constrain(cache_k, ("batch", "kv_seq", None, None))
    cache_v = cfg.constrain(cache_v, ("batch", "kv_seq", None, None))
    q = q.reshape(B, Hkv, G, Dh)
    scores = jnp.einsum("bhgd,bthd->bhgt", q, cache_k) / jnp.sqrt(Dh).astype(x.dtype)
    # valid positions: rolling buffer is full once pos >= Skv
    t = jnp.arange(Skv)
    valid = jnp.where(pos >= Skv, jnp.ones((Skv,), bool), t <= pos)
    scores = jnp.where(valid[None, None, None], scores.astype(jnp.float32),
                       -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bhgt,bthd->bhgd", probs, cache_v).reshape(B, 1, Hq * Dh)
    return out @ lp["wo"], cache_k, cache_v


def _decode_attention_paged(cfg, lp, x, cache_k, cache_v, pos):
    """Read-only-cache decode attention with two-block online softmax.

    The cache contribution is computed shard-locally over (possibly
    sharded) Skv and merged with the current token's K/V analytically, so
    no concat/update ever touches the sharded dimension; GSPMD only
    all-reduces the merged (B, H, G[, D]) statistics.
    """
    B = x.shape[0]
    Hq, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    G = Hq // Hkv
    Skv = cache_k.shape[1]
    q = (x @ lp["wq"]).reshape(B, 1, Hq, Dh)
    k = (x @ lp["wk"]).reshape(B, 1, Hkv, Dh)
    v = (x @ lp["wv"]).reshape(B, 1, Hkv, Dh)
    if cfg.qk_norm:
        q = rms_norm(q, lp["qnorm"])
        k = rms_norm(k, lp["knorm"])
    sin, cos = rotary_embedding(pos[None, None], cfg.d_head, cfg.rope_theta)
    q = apply_rope(q, sin, cos)
    k = apply_rope(k, sin, cos)
    qh = q.reshape(B, Hkv, G, Dh).astype(jnp.float32)
    scale = 1.0 / jnp.sqrt(Dh).astype(jnp.float32)
    s_c = jnp.einsum("bhgd,bthd->bhgt", qh,
                     cache_k.astype(jnp.float32)) * scale
    t = jnp.arange(Skv)
    valid = jnp.where(pos >= Skv, jnp.ones((Skv,), bool), t < pos)
    s_c = jnp.where(valid[None, None, None], s_c, -1e30)
    m_c = jnp.max(s_c, axis=-1)                            # (B,Hkv,G)
    p_c = jnp.exp(s_c - m_c[..., None])
    l_c = jnp.sum(p_c, axis=-1)
    acc_c = jnp.einsum("bhgt,bthd->bhgd", p_c,
                       cache_v.astype(jnp.float32))
    # current token term
    s_t = jnp.einsum("bhgd,bhd->bhg", qh,
                     k[:, 0].astype(jnp.float32)) * scale
    m = jnp.maximum(m_c, s_t)
    w_c = jnp.exp(m_c - m)
    w_t = jnp.exp(s_t - m)
    l = l_c * w_c + w_t
    acc = acc_c * w_c[..., None] + w_t[..., None] \
        * v[:, 0][:, :, None, :].astype(jnp.float32)
    out = (acc / jnp.maximum(l, 1e-30)[..., None])
    out = out.reshape(B, 1, Hq * Dh).astype(x.dtype)
    return out @ lp["wo"], k, v


def serve_step_paged(params, cache, tokens, cfg: TransformerConfig):
    """Decode without cache mutation: returns (logits, k_new, v_new, pos').

    k_new/v_new: (L, B, 1, Hkv, Dh) — the outer serving loop appends them
    to its block-paged cache (host-side or every-W-steps on device).
    """
    B = tokens.shape[0]
    x = params["embed"][tokens].astype(cfg.dtype)
    pos = cache["pos"]

    def body(x, layer):
        lp, ck, cv = layer
        h = rms_norm(x, lp["ln1"])
        a, k_new, v_new = _decode_attention_paged(cfg, lp, h, ck, cv, pos)
        x = x + a
        h2 = rms_norm(x, lp["ln2"])
        if cfg.moe is None:
            f = _dense_ffn(cfg, lp, h2)
        else:
            f, _ = moe_ffn(cfg, lp, h2)
        return x + f, (k_new, v_new)

    if cfg.scan_layers:
        x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], cache["k"],
                                             cache["v"]))
    else:
        kvs = []
        for li, lp in enumerate(params["layers"]):
            x, kv = body(x, (lp, cache["k"][li], cache["v"][li]))
            kvs.append(kv)
        ks = jnp.stack([kv[0] for kv in kvs])
        vs = jnp.stack([kv[1] for kv in kvs])
    x = rms_norm(x, params["ln_f"])
    logits = x[:, 0] @ params["lm_head"]
    return logits, ks, vs, pos + 1


def serve_step(params, cache, tokens, cfg: TransformerConfig):
    """One decode step: tokens (B, 1) + cache -> logits (B, V), new cache."""
    B = tokens.shape[0]
    x = params["embed"][tokens].astype(cfg.dtype)
    x = cfg.constrain(x, ("batch", None, None))
    pos = cache["pos"]

    def body(x, layer):
        lp, ck, cv = layer
        h = rms_norm(x, lp["ln1"])
        a, ck, cv = _decode_attention(cfg, lp, h, ck, cv, pos)
        x = x + a
        h2 = rms_norm(x, lp["ln2"])
        if cfg.moe is None:
            f = _dense_ffn(cfg, lp, h2)
        else:
            f, _ = moe_ffn(cfg, lp, h2)
        return x + f, (ck, cv)

    if cfg.scan_layers:
        x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], cache["k"],
                                             cache["v"]))
    else:
        kvs = []
        for li, lp in enumerate(params["layers"]):
            x, (ck, cv) = body(x, (lp, cache["k"][li], cache["v"][li]))
            kvs.append((ck, cv))
        ks = jnp.stack([kv[0] for kv in kvs])
        vs = jnp.stack([kv[1] for kv in kvs])
    x = rms_norm(x, params["ln_f"])
    logits = x[:, 0] @ params["lm_head"]
    new_cache = {"k": ks, "v": vs, "pos": pos + 1}
    return logits, new_cache
