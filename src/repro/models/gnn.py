"""GNN architectures: GatedGCN, MeshGraphNet, SchNet, GraphSAGE.

All four consume a common ``GraphBatch`` dict of statically-shaped arrays:

  nodes     (N, F)  float   node features
  pos       (N, 3)  float   positions (SchNet; zeros elsewhere)
  edge_src  (E,)    int32   source node per edge
  edge_dst  (E,)    int32   destination node per edge
  edge_x    (E, Fe) float   edge features
  node_mask (N,)    bool    valid nodes (padding = False)
  edge_mask (E,)    bool    valid edges
  graph_id  (N,)    int32   component id (batched small graphs; else zeros)
  labels    (N,) or (G,)    targets

Message passing = gather by edge index -> compute -> ``jax.ops.segment_sum``
scatter (JAX has no sparse SpMM; the segment-op formulation IS the system's
message-passing kernel — see kernels/neighbor_agg for the Pallas fast path
on fixed-fanout batches).

Padding edges point at node 0 with edge_mask False; messages are zeroed
before the scatter so padding never contaminates real nodes.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from .common import layer_norm, mlp_apply, mlp_init, softmax_cross_entropy


def _noop_constrain(x, axes):
    return x


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    name: str
    arch: str                       # gatedgcn | meshgraphnet | schnet | graphsage
    n_layers: int
    d_hidden: int
    d_in: int
    d_edge_in: int
    n_classes: int
    aggregator: str = "sum"
    mlp_layers: int = 2             # meshgraphnet MLP depth
    rbf: int = 300                  # schnet radial basis size
    cutoff: float = 10.0
    task: str = "node_class"        # node_class | node_reg | graph_reg
    dtype: object = jnp.float32
    constrain: Callable = _noop_constrain


def _segment_agg(msgs, dst, n_nodes, how="sum"):
    if how == "sum" or how == "gated":
        return jax.ops.segment_sum(msgs, dst, num_segments=n_nodes)
    if how == "mean":
        s = jax.ops.segment_sum(msgs, dst, num_segments=n_nodes)
        cnt = jax.ops.segment_sum(jnp.ones((msgs.shape[0], 1), msgs.dtype),
                                  dst, num_segments=n_nodes)
        return s / jnp.maximum(cnt, 1.0)
    if how == "max":
        return jax.ops.segment_max(msgs, dst, num_segments=n_nodes)
    raise ValueError(how)


# ------------------------------------------------------------------ params

def init_gnn_params(key, cfg: GNNConfig):
    ks = iter(jax.random.split(key, 4 + 8 * cfg.n_layers))
    d = cfg.d_hidden
    p = {"enc_node": mlp_init(next(ks), [cfg.d_in, d])}
    if cfg.arch == "gatedgcn":
        p["enc_edge"] = mlp_init(next(ks), [max(cfg.d_edge_in, 1), d])
        p["layers"] = [
            {n: mlp_init(next(ks), [d, d]) for n in "ABCDE"}
            | {"ln_h": jnp.ones((d,)), "lb_h": jnp.zeros((d,)),
               "ln_e": jnp.ones((d,)), "lb_e": jnp.zeros((d,))}
            for _ in range(cfg.n_layers)]
    elif cfg.arch == "meshgraphnet":
        p["enc_edge"] = mlp_init(next(ks), [max(cfg.d_edge_in, 1)] +
                                 [d] * cfg.mlp_layers)
        p["enc_node2"] = mlp_init(next(ks), [d] + [d] * cfg.mlp_layers)
        p["layers"] = [
            {"edge_mlp": mlp_init(next(ks), [3 * d] + [d] * cfg.mlp_layers),
             "node_mlp": mlp_init(next(ks), [2 * d] + [d] * cfg.mlp_layers),
             "ln_e": jnp.ones((d,)), "lb_e": jnp.zeros((d,)),
             "ln_h": jnp.ones((d,)), "lb_h": jnp.zeros((d,))}
            for _ in range(cfg.n_layers)]
    elif cfg.arch == "schnet":
        p["layers"] = [
            {"filter": mlp_init(next(ks), [cfg.rbf, d, d]),
             "w_in": mlp_init(next(ks), [d, d]),
             "out": mlp_init(next(ks), [d, d, d])}
            for _ in range(cfg.n_layers)]
    elif cfg.arch == "graphsage":
        p["layers"] = [
            {"w_self": mlp_init(next(ks), [d, d]),
             "w_nbr": mlp_init(next(ks), [d, d])}
            for _ in range(cfg.n_layers)]
    else:
        raise ValueError(cfg.arch)
    out_dim = cfg.n_classes if cfg.task == "node_class" else \
        (1 if cfg.task != "node_reg" else cfg.d_in)
    p["dec"] = mlp_init(next(ks), [d, d, out_dim])
    return p


# ----------------------------------------------------------------- forward

def _rbf_expand(dist, n_rbf: int, cutoff: float):
    centers = jnp.linspace(0.0, cutoff, n_rbf)
    gamma = 10.0 / cutoff
    return jnp.exp(-gamma * (dist[:, None] - centers[None, :]) ** 2)


def gnn_forward(params, batch, cfg: GNNConfig):
    """Returns per-node outputs (N, out_dim) (graph tasks pool afterwards)."""
    N = batch["nodes"].shape[0]
    src, dst = batch["edge_src"], batch["edge_dst"]
    emask = batch["edge_mask"][:, None].astype(cfg.dtype)
    cons = cfg.constrain

    h = mlp_apply(params["enc_node"], batch["nodes"].astype(cfg.dtype), 1)
    h = cons(h, ("nodes", None))

    if cfg.arch == "gatedgcn":
        e = mlp_apply(params["enc_edge"], batch["edge_x"].astype(cfg.dtype), 1)
        for lp in params["layers"]:
            hs, hd = h[src], h[dst]
            e_new = (mlp_apply(lp["C"], e, 1) + mlp_apply(lp["D"], hd, 1)
                     + mlp_apply(lp["E"], hs, 1))
            e = e + jax.nn.relu(layer_norm(e_new, lp["ln_e"], lp["lb_e"]))
            eta = jax.nn.sigmoid(e) * emask
            denom = _segment_agg(eta, dst, N, "sum") + 1e-6
            msg = eta * mlp_apply(lp["B"], hs, 1) * emask
            agg = _segment_agg(msg, dst, N, "sum") / denom
            agg = cons(agg, ("nodes", None))
            h_new = mlp_apply(lp["A"], h, 1) + agg
            h = h + jax.nn.relu(layer_norm(h_new, lp["ln_h"], lp["lb_h"]))
    elif cfg.arch == "meshgraphnet":
        e = mlp_apply(params["enc_edge"], batch["edge_x"].astype(cfg.dtype),
                      cfg.mlp_layers)
        h = mlp_apply(params["enc_node2"], h, cfg.mlp_layers)
        for lp in params["layers"]:
            cat_e = jnp.concatenate([e, h[src], h[dst]], axis=-1)
            e = e + layer_norm(mlp_apply(lp["edge_mlp"], cat_e,
                                         cfg.mlp_layers),
                               lp["ln_e"], lp["lb_e"])
            agg = _segment_agg(e * emask, dst, N, cfg.aggregator)
            agg = cons(agg, ("nodes", None))
            cat_h = jnp.concatenate([h, agg], axis=-1)
            h = h + layer_norm(mlp_apply(lp["node_mlp"], cat_h,
                                         cfg.mlp_layers),
                               lp["ln_h"], lp["lb_h"])
    elif cfg.arch == "schnet":
        dvec = batch["pos"][src] - batch["pos"][dst]
        dist = jnp.sqrt(jnp.sum(dvec * dvec, axis=-1) + 1e-12)
        rbf = _rbf_expand(dist, cfg.rbf, cfg.cutoff).astype(cfg.dtype)
        cut = 0.5 * (jnp.cos(jnp.pi * dist / cfg.cutoff) + 1.0)
        cut = jnp.where(dist <= cfg.cutoff, cut, 0.0)[:, None].astype(cfg.dtype)
        for lp in params["layers"]:
            w = mlp_apply(lp["filter"], rbf, 2, act=jax.nn.softplus) * cut
            xin = mlp_apply(lp["w_in"], h, 1)
            msg = xin[src] * w * emask
            agg = _segment_agg(msg, dst, N, "sum")
            agg = cons(agg, ("nodes", None))
            h = h + mlp_apply(lp["out"], agg, 2, act=jax.nn.softplus)
    elif cfg.arch == "graphsage":
        for lp in params["layers"]:
            msg = h[src] * emask
            agg = _segment_agg(msg, dst, N, "mean")
            agg = cons(agg, ("nodes", None))
            h = jax.nn.relu(mlp_apply(lp["w_self"], h, 1)
                            + mlp_apply(lp["w_nbr"], agg, 1))
            h = h / jnp.maximum(
                jnp.linalg.norm(h, axis=-1, keepdims=True), 1e-6)
    else:
        raise ValueError(cfg.arch)

    return mlp_apply(params["dec"], h, 2)


def gnn_loss(params, batch, cfg: GNNConfig):
    out = gnn_forward(params, batch, cfg)
    nmask = batch["node_mask"].astype(jnp.float32)
    if cfg.task == "node_class":
        return softmax_cross_entropy(out, batch["labels"], mask=nmask)
    if cfg.task == "node_reg":
        err = jnp.sum((out - batch["targets"]) ** 2, axis=-1)
        return jnp.sum(err * nmask) / jnp.maximum(jnp.sum(nmask), 1.0)
    if cfg.task == "graph_reg":
        G = batch["graph_targets"].shape[0]
        pooled = jax.ops.segment_sum(out * nmask[:, None], batch["graph_id"],
                                     num_segments=G)[:, 0]
        return jnp.mean((pooled - batch["graph_targets"]) ** 2)
    raise ValueError(cfg.task)
