"""Two-tower retrieval model (YouTube-style, RecSys'19).

EmbeddingBag is built from first principles (JAX has no native one):
``jnp.take`` over the table + mean over the bag slots, with -1 padding.
Sparse feature fields: ``n_fields`` multi-hot bags per tower; bag
embeddings are concatenated and fed to the tower MLP (1024-512-256).

Training uses in-batch sampled softmax with logQ correction; serving
scores a query against a candidate embedding matrix (``retrieval_cand``).

The embedding tables are the HYPE integration point: rows co-accessed by
the same query form a hypergraph (rows = vertices, queries = hyperedges);
partitioning rows with HYPE minimizes cross-shard lookups — exactly the
paper's distributed-data-placement motivation (§I). See repro/dist.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from .common import embed_init, mlp_apply, mlp_init


def _noop_constrain(x, axes):
    return x


@dataclasses.dataclass(frozen=True)
class TwoTowerConfig:
    name: str
    embed_dim: int = 256
    tower_dims: tuple = (1024, 512, 256)
    n_fields: int = 4              # sparse feature fields per tower
    bag_size: int = 8              # multi-hot ids per field (padded, -1)
    user_vocab: int = 10_000_000
    item_vocab: int = 10_000_000
    temperature: float = 0.05
    dtype: object = jnp.float32
    constrain: Callable = _noop_constrain


def init_twotower_params(key, cfg: TwoTowerConfig):
    ks = jax.random.split(key, 4)
    d_in = cfg.n_fields * cfg.embed_dim
    assert d_in == cfg.tower_dims[0], "field concat must match tower input"
    return {
        "user_table": embed_init(ks[0], cfg.user_vocab, cfg.embed_dim,
                                 cfg.dtype),
        "item_table": embed_init(ks[1], cfg.item_vocab, cfg.embed_dim,
                                 cfg.dtype),
        "user_tower": mlp_init(ks[2], (d_in,) + cfg.tower_dims[1:], cfg.dtype),
        "item_tower": mlp_init(ks[3], (d_in,) + cfg.tower_dims[1:], cfg.dtype),
    }


def embedding_bag(table, ids, cfg, combine="mean"):
    """ids: (..., bag) int32 with -1 padding -> (..., embed_dim)."""
    valid = ids >= 0
    safe = jnp.where(valid, ids, 0)
    vecs = jnp.take(table, safe, axis=0)          # (..., bag, d)
    vecs = jnp.where(valid[..., None], vecs, 0)
    if combine == "sum":
        return jnp.sum(vecs, axis=-2)
    cnt = jnp.maximum(jnp.sum(valid, axis=-1, keepdims=True), 1)
    return jnp.sum(vecs, axis=-2) / cnt.astype(vecs.dtype)


def tower(params_mlp, table, ids, cfg: TwoTowerConfig):
    """ids: (B, n_fields, bag) -> L2-normalized embeddings (B, out)."""
    bags = embedding_bag(table, ids, cfg)          # (B, n_fields, d)
    x = bags.reshape(ids.shape[0], cfg.n_fields * cfg.embed_dim)
    x = cfg.constrain(x, ("batch", None))
    x = mlp_apply(params_mlp, x, len(cfg.tower_dims) - 1)
    return x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), 1e-6)


def twotower_loss(params, batch, cfg: TwoTowerConfig):
    """In-batch sampled softmax with logQ correction.

    batch: {user_ids (B,F,bag), item_ids (B,F,bag), item_logq (B,)}
    """
    u = tower(params["user_tower"], params["user_table"], batch["user_ids"],
              cfg)
    i = tower(params["item_tower"], params["item_table"], batch["item_ids"],
              cfg)
    logits = (u @ i.T) / cfg.temperature           # (B, B)
    logits = cfg.constrain(logits, ("batch", None))
    logits = logits - batch["item_logq"][None, :]  # logQ correction
    labels = jnp.arange(u.shape[0])
    logz = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(logits.astype(jnp.float32),
                               labels[:, None], axis=-1)[:, 0]
    return jnp.mean(logz - gold)


def score_batch(params, batch, cfg: TwoTowerConfig):
    """Online/bulk serving: dot(user emb, item emb) per row."""
    u = tower(params["user_tower"], params["user_table"], batch["user_ids"],
              cfg)
    i = tower(params["item_tower"], params["item_table"], batch["item_ids"],
              cfg)
    return jnp.sum(u * i, axis=-1)


def retrieve(params, batch, cfg: TwoTowerConfig, top_k: int = 100):
    """One query vs. a precomputed candidate matrix (n_cand, out_dim)."""
    u = tower(params["user_tower"], params["user_table"], batch["user_ids"],
              cfg)                                  # (1, out)
    cands = batch["cand_embs"]                      # (n_cand, out)
    cands = cfg.constrain(cands, ("cands", None))
    scores = (u @ cands.T)[0]                       # (n_cand,)
    return jax.lax.top_k(scores, top_k)
