"""Shared model building blocks (pure-JAX, pytree params, no framework).

Conventions:
  * params are nested dicts of jax.Arrays;
  * every ``init_*`` takes an explicit PRNG key and returns params;
  * compute dtype is bf16 by default, reductions/norms in fp32;
  * sharding is applied externally via NamedSharding / sharding
    constraints — the model code is mesh-agnostic.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

DEFAULT_DTYPE = jnp.bfloat16


# --------------------------------------------------------------------- init

def dense_init(key, in_dim: int, out_dim: int, dtype=DEFAULT_DTYPE):
    scale = 1.0 / np.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim), jnp.float32)
            * scale).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype=DEFAULT_DTYPE):
    return (jax.random.normal(key, (vocab, dim), jnp.float32) * 0.02).astype(dtype)


def mlp_init(key, dims, dtype=DEFAULT_DTYPE):
    """Params for an MLP with layer dims [d0, d1, ..., dk]."""
    keys = jax.random.split(key, len(dims) - 1)
    return {
        f"w{i}": dense_init(keys[i], dims[i], dims[i + 1], dtype)
        for i in range(len(dims) - 1)
    } | {
        f"b{i}": jnp.zeros((dims[i + 1],), dtype) for i in range(len(dims) - 1)
    }


def mlp_apply(params, x, n_layers: int, act=jax.nn.relu, final_act=False):
    for i in range(n_layers):
        x = x @ params[f"w{i}"] + params[f"b{i}"]
        if i < n_layers - 1 or final_act:
            x = act(x)
    return x


# ------------------------------------------------------------------- norms

def rms_norm(x, gamma, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(dtype) * gamma


def layer_norm(x, gamma, beta, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(dtype) * gamma + beta


# -------------------------------------------------------------------- rope

def rotary_embedding(positions, d_head: int, theta: float = 10_000.0):
    """Returns (sin, cos) of shape (..., d_head//2)."""
    half = d_head // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.sin(angles), jnp.cos(angles)


def apply_rope(x, sin, cos):
    """x: (..., S, H, D); sin/cos: (..., S, D//2) broadcast over heads."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    sin = sin[..., None, :]
    cos = cos[..., None, :]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


# -------------------------------------------------------------------- loss

def softmax_cross_entropy(logits, labels, mask=None):
    """Mean token CE in fp32. logits (..., V), labels (...,) int."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
