"""Mixture-of-Experts FFN with capacity-based local dispatch.

GShard/Switch-style top-k routing with a per-batch-row token queue:
positions inside each expert's queue are computed by a cumulative sum over
the row's slots, so dispatch stays *local to the data shard* (no cross-host
permutation — the trade-off production systems make when experts are
replicated or tensor-parallel rather than expert-parallel across hosts).

FLOPs scale with top_k (not n_experts): each expert processes at most
``capacity = S * top_k / n_experts * capacity_factor`` tokens per row.
Overflowed tokens are dropped (standard GShard semantics); the auxiliary
load-balancing loss keeps drop rates low.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .common import dense_init


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25


def init_moe_layer(key, d_model: int, d_ff: int, moe: MoEConfig, dtype):
    ks = jax.random.split(key, 4)
    E = moe.n_experts

    def stack(k, din, dout):
        return jax.vmap(lambda kk: dense_init(kk, din, dout, dtype))(
            jax.random.split(k, E))

    return {
        "router": dense_init(ks[0], d_model, E, jnp.float32),
        "moe_gate": stack(ks[1], d_model, d_ff),   # (E, d, ff)
        "moe_up": stack(ks[2], d_model, d_ff),
        "moe_down": stack(ks[3], d_ff, d_model),   # (E, ff, d)
    }


def moe_ffn(cfg, lp, x):
    """x: (B, S, d) -> (B, S, d), aux load-balancing loss (fp32 scalar)."""
    moe = cfg.moe
    B, S, d = x.shape
    E, K = moe.n_experts, moe.top_k
    cf = getattr(cfg, "moe_cf_override", None) or moe.capacity_factor
    C = max(1, int(S * K / E * cf))
    acc_t = (jnp.bfloat16 if getattr(cfg, "moe_accum_bf16", False)
             else jnp.float32)

    logits = (x.astype(jnp.float32) @ lp["router"])        # (B, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, K)                 # (B, S, K)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    # ---- aux loss (Switch): E * sum_e f_e * p_e ----
    me = jnp.mean(probs, axis=(0, 1))                       # (E,)
    fe = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_i, E, dtype=jnp.float32), axis=2),
        axis=(0, 1))
    aux = E * jnp.sum(me * fe / K)

    # ---- position of each slot in its expert's queue (per row) ----
    flat_e = top_i.reshape(B, S * K)                        # (B, S*K)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)     # (B, S*K, E)
    pos_in_e = jnp.cumsum(onehot, axis=1) - onehot          # pos before slot
    pos = jnp.sum(pos_in_e * onehot, axis=-1)               # (B, S*K)
    keep = pos < C
    pos = jnp.minimum(pos, C - 1)

    # ---- dispatch: scatter tokens into (B, E, C, d) ----
    xk = jnp.repeat(x, K, axis=1).reshape(B, S * K, d)      # slot -> token
    xk = jnp.where(keep[..., None], xk, 0)

    def scatter_row(buf, e_row, p_row, x_row):
        return buf.at[e_row, p_row].add(x_row)
    buf = jax.vmap(scatter_row)(
        jnp.zeros((B, E, C, d), x.dtype), flat_e, pos, xk)
    shard_c = getattr(cfg, "moe_shard_c", False)
    buf = cfg.constrain(buf, ("batch", None,
                              "expert_c" if shard_c else None, None))

    # ---- expert computation (batched over E) ----
    # moe_accum_bf16 keeps the GEMM accumulation (and hence GSPMD's
    # backward partial-sum collectives) in bf16; the default leaves the
    # accumulation dtype to XLA (fp32 on TPU).
    ekw = ({"preferred_element_type": jnp.bfloat16}
           if acc_t == jnp.bfloat16 else {})
    h = jnp.einsum("becd,edf->becf", buf, lp["moe_gate"], **ekw)
    u = jnp.einsum("becd,edf->becf", buf, lp["moe_up"], **ekw)
    h = (jax.nn.silu(h) * u).astype(x.dtype)
    h = cfg.constrain(h, ("batch", None,
                          "expert_c" if shard_c else None,
                          None if shard_c else "mlp"))
    out_buf = jnp.einsum("becf,efd->becd", h, lp["moe_down"],
                         **ekw).astype(x.dtype)

    # ---- combine: gather each slot's output, weight, sum over K ----
    def gather_row(buf_row, e_row, p_row):
        return buf_row[e_row, p_row]
    slot_out = jax.vmap(gather_row)(out_buf, flat_e, pos)   # (B, S*K, d)
    w = (top_p.reshape(B, S * K) * keep).astype(x.dtype)
    out = jnp.sum((slot_out * w[..., None]).reshape(B, S, K, d), axis=2)
    return out, aux.astype(jnp.float32)
