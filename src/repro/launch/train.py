"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Builds the arch's cell on the host mesh (all local devices), initializes
real parameters, and drives fault-tolerant training on the synthetic
stream. This is the single-host entry point; on a real cluster each
process runs the same binary with jax.distributed initialized and the
production mesh from launch/mesh.py.

    PYTHONPATH=src python -m repro.launch.train --arch stablelm-3b \
        --reduced --steps 50
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_arch
from repro.launch.cells import build_cell
from repro.models import transformer as tf_mod
from repro.train.fault_tolerance import FTConfig, run_training
from repro.train.optimizer import init_adamw
from repro.data.pipeline import TokenStream, RecsysStream
from repro.data.graphs import build_graph_batch, random_graph


def _gnn_batches(arch, plan, seed=0):
    spec = plan.args[2]
    n, e = spec["nodes"].shape[0], spec["edge_src"].shape[0]
    src, dst = random_graph(n, max(e / n, 1.0), seed=seed)
    src, dst = src[:e], dst[:e]
    base = build_graph_batch(n, src, dst, spec["nodes"].shape[1],
                             int(spec["labels"].shape[0] and 5) or 5,
                             seed=seed, pad_nodes=n, pad_edges=e)
    base = {k: jnp.asarray(v) for k, v in base.items() if k in spec}
    return lambda step: base


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family config (CPU-trainable)")
    ap.add_argument("--ckpt_dir", default="artifacts/ckpt")
    args = ap.parse_args(argv)

    arch = get_arch(args.arch)
    shape = args.shape or {"lm": "train_4k", "gnn": "full_graph_sm",
                           "recsys": "train_batch"}[arch.family]
    plan = build_cell(args.arch, shape, mesh=None, reduced=args.reduced)
    assert plan.kind == "train", "train.py drives train cells"

    rng = np.random.default_rng(0)
    if arch.family == "lm":
        cfg = arch.build_cfg(reduced=args.reduced)
        params = tf_mod.init_params(jax.random.PRNGKey(0), cfg)
        spec = plan.args[2]["tokens"]
        accum, mb, seq = spec.shape
        stream = TokenStream(cfg.vocab, accum * mb, seq, seed=0)
        def batch_at(step):
            b = stream.batch_at(step)
            return {k: jnp.asarray(v.reshape(accum, mb, seq))
                    for k, v in b.items()}
    elif arch.family == "gnn":
        params = jax.tree.map(
            lambda s: jnp.asarray(
                rng.normal(size=s.shape).astype(np.float32) * 0.1, s.dtype)
            if s.dtype != jnp.int32 else jnp.zeros(s.shape, s.dtype),
            plan.args[0])
        batch_at = _gnn_batches(arch, plan)
    else:
        cfgr = arch.build_cfg(reduced=args.reduced)
        from repro.models.recsys import init_twotower_params
        params = init_twotower_params(jax.random.PRNGKey(0), cfgr)
        spec = plan.args[2]["user_ids"]
        stream = RecsysStream(cfgr.user_vocab, cfgr.item_vocab,
                              spec.shape[0], n_fields=spec.shape[1],
                              bag=spec.shape[2])
        batch_at = lambda step: {k: jnp.asarray(v) for k, v in
                                 stream.batch_at(step).items()}

    from repro.launch.cells import _OPT, _DEFAULT_OPT
    opt_cfg = _OPT.get(args.arch, _DEFAULT_OPT)
    opt = init_adamw(params, opt_cfg)
    step_fn = jax.jit(plan.fn)

    os.makedirs(args.ckpt_dir, exist_ok=True)
    t0 = time.time()
    res = run_training(step_fn, (params, opt), None, args.steps,
                       FTConfig(ckpt_dir=os.path.join(args.ckpt_dir,
                                                      args.arch)),
                       batch_at=batch_at)
    losses = [m["loss"] for m in res.metrics_history if "loss" in m]
    print(f"{args.arch}/{shape}: {res.steps_done} steps in "
          f"{time.time() - t0:.1f}s; loss {losses[0]:.4f} -> "
          f"{losses[-1]:.4f}")


if __name__ == "__main__":
    main()
