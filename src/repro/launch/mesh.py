"""Production mesh construction.

Target hardware: TPU v5e pods, 256 chips per pod (16x16), optionally
2 pods = 512 chips. Axes:

  single-pod:  (16, 16)        ("data", "model")
  multi-pod:   (2, 16, 16)     ("pod", "data", "model")

The "pod" axis carries only data parallelism (+ int8-compressed gradient
all-reduces) because inter-pod links are the slowest tier; "model" carries
tensor/expert parallelism within a pod's fast ICI.

Functions, not module constants: importing this module must never touch
jax device state (the dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int | None = None, model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    if data is None:
        data = n // model
    return jax.make_mesh((data, model), ("data", "model"))


def flat_device_axis(mesh) -> int:
    """Total device count of a mesh (for flattened shard_map layouts)."""
    return int(np.prod(mesh.devices.shape))
