import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# §Perf hillclimb driver — hypothesis -> change -> measure -> validate.
# Own process (512 placeholder devices). Results land in
# artifacts/perf/<tag>.json and are summarized into EXPERIMENTS.md §Perf.
#
# NOTE: repro.dist is an optional subsystem; every import of it in this
# module MUST stay function-local (lazy) so that importing the module —
# which the test suite and tooling do — works without it.
#
#   PYTHONPATH=src python -m repro.launch.perf_experiments --exp all

import argparse
import json
import math
import time

import jax
import numpy as np

from repro.launch.dryrun import run_cell, _compile_plan, _costs_of
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import roofline_report, model_flops

PERF_DIR = "artifacts/perf"


def _show(name, rec):
    r = rec["roofline"]
    print(f"{name:42s} compute={r['compute_s']:.4f}s "
          f"memory={r['memory_s']:.4f}s coll={r['collective_s']:.4f}s "
          f"bound={r['bound']} temp_GB="
          f"{(rec['memory']['temp_bytes'] or 0) / 1e9:.1f}", flush=True)


# ------------------------------------------------------------------ exp A
def exp_llama_train():
    """A: llama3-405b train_4k (multi) — memory-dominated.

    A1 hypothesis: the remat-saved residuals ((mb/32)x4096x16384 bf16 x126
    layers ≈ tens of GB/device) dominate temp memory; Megatron
    sequence-parallel sharding of the residual (seq over the 16-way model
    axis) cuts saved-activation bytes ~16x at the cost of per-layer
    gather/scatter transitions (wire delta expected small vs the existing
    TP all-reduces)."""
    base = run_cell("llama3-405b", "train_4k", "multi", force=False)
    _show("A0 baseline (fsdp+tp, remat)", base)
    var = run_cell("llama3-405b", "train_4k", "multi",
                   variant={"seq_shard": True}, tag_suffix="__seqshard",
                   out_dir=PERF_DIR, force=True)
    _show("A1 +seq_shard residuals", var)
    # A2: never materialize (S,S) scores — Rabe-Staats blockwise attention
    # (jnp analogue of the Pallas flash kernel). Hypothesis: the memory
    # term is dominated by attention-score traffic; tiling K by 1024 cuts
    # score bytes ~4x per layer with unchanged matmul flops.
    a2 = run_cell("llama3-405b", "train_4k", "multi",
                  variant={"attention_impl": "blockwise"},
                  tag_suffix="__blockwise", out_dir=PERF_DIR, force=True)
    _show("A2 blockwise attention", a2)
    return {"A0": base, "A1": var, "A2": a2}


# ------------------------------------------------------------------ exp B
def exp_llama_decode():
    """B: llama3-405b decode_32k (single) — pathological collective term.

    B0 baseline shards the cache on kv_seq; the single-position
    dynamic-update-slice on the sharded dim makes GSPMD replicate the
    cache (SPMD 'involuntary full rematerialization' warnings) ->
    ~100 GB wire per decoded token.
    B1 hypothesis: shard the cache on kv_heads instead (8 heads over the
    16-way axis — uneven, GSPMD pads 2x) so the cache update is local;
    wire should collapse to the logits/output collectives.
    B2 hypothesis: batch-only sharding (B=128 over data) — local update,
    but cache memory 16x larger per device than B1."""
    base = run_cell("llama3-405b", "decode_32k", "single", force=False)
    _show("B0 baseline (cache on kv_seq)", base)
    b1 = run_cell("llama3-405b", "decode_32k", "single",
                  variant={"cache_shard": "kv_heads"},
                  tag_suffix="__kvheads", out_dir=PERF_DIR, force=True)
    _show("B1 cache on kv_heads (uneven)", b1)
    b2 = run_cell("llama3-405b", "decode_32k", "single",
                  variant={"cache_shard": "batch_model"},
                  tag_suffix="__batchmodel", out_dir=PERF_DIR, force=True)
    _show("B2 cache on batch only", b2)
    # B3: paged decode — cache is a read-only input; the per-layer
    # dynamic-update-slice (the replication source, ~751 MB wire/layer in
    # B0's measurement) disappears; shard-local partial softmax merges
    # with the current token analytically.
    b3 = run_cell("llama3-405b", "decode_32k", "single",
                  variant={"decode_paged": True},
                  tag_suffix="__paged", out_dir=PERF_DIR, force=True)
    _show("B3 paged decode (read-only cache)", b3)
    return {"B0": base, "B1": b1, "B2": b2, "B3": b3}


# ------------------------------------------------------------------ exp C
def _measure_beta(k=64, scale=20):
    """Boundary fraction of HYPE vs random on a products-like graph
    (scaled 1/scale in nodes, same mean degree)."""
    from repro.core.hype import HypeParams, hype_partition
    from repro.placement.partitioned_gnn import graph_to_hypergraph
    rng = np.random.default_rng(0)
    n = 2_449_029 // scale
    deg = 25
    src = rng.integers(0, n, n * deg // 2)
    u = rng.random(src.size)
    # heavy-tailed local displacement (hierarchical communities, like the
    # co-purchase graph); a small global tail
    disp = np.minimum((3.0 * u ** (-1.0 / 0.9)).astype(np.int64), n // 2)
    local = rng.random(src.size) < 0.995
    dst = np.where(local, (src + disp) % n, rng.integers(0, n, src.size))
    keep = src != dst
    src, dst = src[keep], dst[keep]
    hg = graph_to_hypergraph(n, src, dst)

    def beta_of(asg):
        part = np.asarray(asg, np.int64)
        rem = part[src] != part[dst]
        b = np.unique(part[src[rem]] * np.int64(n) + src[rem])
        counts = np.bincount(b // n, minlength=k)
        n_local = int(np.bincount(part, minlength=k).max())
        return float(counts.max()) / n_local

    t0 = time.time()
    asg_h = hype_partition(hg, k, HypeParams(seed=0))
    t_hype = time.time() - t0
    asg_r = rng.integers(0, k, n).astype(np.int32)
    bh, br = beta_of(asg_h), beta_of(asg_r)
    print(f"   beta(hype)={bh:.3f} beta(random)={br:.3f} "
          f"(measured at n={n}, k={k}, hype {t_hype:.0f}s)", flush=True)
    return bh, br


def exp_gnn_halo(beta_pair=None):
    """C: gatedgcn x ogb_products (single) — the paper's technique as the
    optimization.

    C0 baseline: flat XLA path — GSPMD resolves each edge-sharded
    segment_sum with full (N, d) all-reduces: collective-bound.
    C1 hypothesis: HYPE-partitioned halo exchange replaces the all-reduce
    with one all-gather of boundary rows per layer; wire per device drops
    from ~N*d to k*B_max*d where B_max = beta * n_local, with beta
    measured from an actual HYPE partition (vs random placement as C2)."""
    from repro.dist.halo_gnn import halo_gatedgcn_specs, \
        make_halo_gatedgcn_step
    base = run_cell("gatedgcn", "ogb_products", "single", force=False)
    _show("C0 baseline (flat XLA scatter)", base)

    if beta_pair is None:
        beta_pair = _measure_beta()
    bh, br = beta_pair
    mesh = make_production_mesh(multi_pod=False)
    n_dev = 256
    out = {"C0": base}
    for tag, beta in (("C1_hype", bh), ("C2_random", br)):
        specs, dims = halo_gatedgcn_specs(
            2_449_029, 61_859_140, 100, n_dev, beta, 70)
        step, p_abs, o_abs = make_halo_gatedgcn_step(
            mesh, n_dev, 100, 70, 16, 47)
        import jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        spec = P(("data", "model"))
        b_sh = {k2: NamedSharding(mesh, spec) for k2 in specs}
        p_sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), p_abs)
        o_sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), o_abs,
                            is_leaf=lambda x: hasattr(x, "shape"))
        jitted = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                         out_shardings=None)
        t0 = time.time()
        compiled = jitted.lower(p_abs, o_abs, specs).compile()
        costs = _costs_of(compiled)
        mem = compiled.memory_analysis()
        rep = roofline_report(
            flops_per_device=costs["flops"],
            bytes_per_device=costs["bytes"],
            collective_wire_bytes=costs["wire"], n_devices=n_dev,
            model_flops_global=model_flops("gatedgcn", "ogb_products",
                                           {"d_hidden": 70,
                                            "n_layers": 16}))
        rec = {"arch": "gatedgcn", "shape": "ogb_products",
               "mesh": "single", "variant": {"halo": tag, "beta": beta},
               "dims": dims, "compile_s": round(time.time() - t0, 1),
               "cost_per_device": {k2: costs[k2] for k2 in
                                   ("flops", "bytes", "wire")},
               "memory": {"temp_bytes":
                          getattr(mem, "temp_size_in_bytes", None)},
               "roofline": rep}
        os.makedirs(PERF_DIR, exist_ok=True)
        with open(os.path.join(
                PERF_DIR, f"gatedgcn__ogb_products__single__{tag}.json"),
                "w") as f:
            json.dump(rec, f, indent=1)
        _show(f"{tag} (beta={beta:.3f})", rec)
        out[tag] = rec
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--exp", default="all",
                    choices=["all", "llama_train", "llama_decode",
                             "gnn_halo"])
    args = ap.parse_args(argv)
    os.makedirs(PERF_DIR, exist_ok=True)
    if args.exp in ("all", "llama_train"):
        exp_llama_train()
    if args.exp in ("all", "llama_decode"):
        exp_llama_decode()
    if args.exp in ("all", "gnn_halo"):
        exp_gnn_halo()


if __name__ == "__main__":
    main()
