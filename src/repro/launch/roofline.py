"""Roofline math: TPU v5e constants, HLO collective parsing, term report.

    compute term    = HLO_FLOPs / (chips * peak_FLOP/s)
    memory term     = HLO_bytes / (chips * HBM_bw)
    collective term = wire_bytes / (chips * link_bw)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()`` (whole-program
totals across devices for an SPMD module lowered at 512 devices — XLA
reports per-module totals; we treat them as global and divide by chips).
Collective bytes are parsed from the post-SPMD HLO text: for every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
we take the result shape and apply standard ring-cost wire-byte formulas.
"""
from __future__ import annotations

import math
import re
from collections import defaultdict

# TPU v5e, per chip
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link (~2 links usable per axis)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"(\w[\w.\-]*)\s*=\s*(?:\([^)]*\)|(\w+)\[([\d,]*)\][^=]*?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.M)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _group_size(line: str, default: int) -> int:
    # iota format: replica_groups=[16,32]<=[512] -> group size = dims[1]
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=", line)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([^}]*)\}", line)
    if m:
        return m.group(1).count(",") + 1
    return default


def collective_bytes_from_hlo(hlo: str, default_group: int = 256) -> dict:
    """Wire bytes per device by collective kind (ring formulas)."""
    out = defaultdict(float)
    counts = defaultdict(int)
    for line in hlo.splitlines():
        line_s = line.strip()
        m = re.match(
            r"%?[\w.\-]+\s*=\s*(.+?)\s*"
            r"(all-reduce|all-gather|reduce-scatter|all-to-all|"
            r"collective-permute)(?:-start)?\(", line_s)
        if not m:
            continue
        shape_part, kind = m.group(1), m.group(2)
        shapes = _SHAPE_RE.findall(shape_part)
        if not shapes:
            continue
        result_bytes = sum(_shape_bytes(dt, dims) for dt, dims in shapes
                           if dt in _DTYPE_BYTES)
        g = _group_size(line_s, default_group)
        if kind == "all-reduce":
            wire = 2 * (g - 1) / max(g, 1) * result_bytes
        elif kind == "all-gather":
            wire = (g - 1) / max(g, 1) * result_bytes
        elif kind == "reduce-scatter":
            wire = (g - 1) * result_bytes
        elif kind == "all-to-all":
            wire = (g - 1) / max(g, 1) * result_bytes
        else:  # collective-permute
            wire = result_bytes
        out[kind] += wire
        counts[kind] += 1
    return {"wire_bytes_per_device": dict(out),
            "op_counts": dict(counts),
            "total_wire_bytes": float(sum(out.values()))}


def roofline_report(*, flops_per_device: float, bytes_per_device: float,
                    collective_wire_bytes: float, n_devices: int,
                    model_flops_global: float | None) -> dict:
    """All inputs are per-device (the compiled module is the per-device SPMD
    program) except MODEL_FLOPS, which is the global useful-work estimate.
    """
    compute_s = flops_per_device / PEAK_FLOPS
    memory_s = bytes_per_device / HBM_BW
    coll_s = collective_wire_bytes / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": coll_s}
    bound = max(terms, key=terms.get).replace("_s", "")
    rep = {**terms, "bound": bound,
           "step_time_lower_bound_s": max(terms.values())}
    if model_flops_global:
        hlo_global = flops_per_device * n_devices
        rep["model_flops"] = model_flops_global
        rep["useful_flops_ratio"] = (model_flops_global / hlo_global
                                     if hlo_global else None)
        rep["roofline_fraction"] = (
            model_flops_global / (n_devices * PEAK_FLOPS)
            / max(max(terms.values()), 1e-12))
    return rep


def model_flops(arch_id: str, shape: str, meta: dict) -> float | None:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE) for LM training;
    2*N*D for single forward (prefill/decode counts D=tokens processed)."""
    from repro.configs import get_arch
    arch = get_arch(arch_id)
    if arch.family == "lm":
        sp = arch.shapes[shape].meta
        n_active = meta.get("params_active")
        if shape == "train_4k":
            D = sp["batch"] * sp["seq"]
            return 6.0 * n_active * D
        if shape == "prefill_32k":
            D = sp["batch"] * sp["seq"]
            return 2.0 * n_active * D
        # decode: one token per sequence
        return 2.0 * n_active * sp["batch"]
    if arch.family == "gnn":
        sp = arch.shapes[shape].meta
        d = meta.get("d_hidden", 128)
        L = meta.get("n_layers", 2)
        E_, N_ = sp["edges"], sp["nodes"]
        # per-arch per-layer MAC counts (x2 flops/MAC, x3 for fwd+bwd)
        if arch_id == "gatedgcn":
            fwd = (E_ * 4 * d * d + N_ * 2 * d * d) * 2.0 * L
        elif arch_id == "meshgraphnet":
            fwd = (E_ * 4 * d * d + N_ * 3 * d * d) * 2.0 * L
        elif arch_id == "schnet":
            rbf = 300
            fwd = (E_ * (rbf * d + d * d) + N_ * 3 * d * d) * 2.0 * L
        else:  # graphsage: aggregation is add-only; MLPs per node
            fwd = (N_ * 2 * d * d) * 2.0 * L
        return 3.0 * fwd
    if arch.family == "recsys":
        sp = arch.shapes[shape].meta
        d_tower = 1024 * 512 + 512 * 256
        out_dim = 256
        if shape == "retrieval_cand":
            return 2.0 * sp["n_cand"] * out_dim
        B = sp["batch"]
        towers = B * 2 * (2.0 * d_tower)
        interact = (2.0 * B * B * out_dim if shape == "train_batch"
                    else 2.0 * B * out_dim)
        mult = 3.0 if shape == "train_batch" else 1.0
        return mult * (towers + interact)
    return None
