import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Multi-pod dry-run: lower + compile every (arch x shape) cell on the
# production meshes and extract roofline inputs.
_DOC = """

MUST be run as its own process (the XLA flag above pins 512 host devices
before any jax import):

    PYTHONPATH=src python -m repro.launch.dryrun --cells all
    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-405b \
        --shape train_4k --mesh single

Per cell it records into artifacts/dryrun/<arch>__<shape>__<mesh>.json:
  * memory_analysis (bytes per device: args/outputs/temps/total)
  * cost_analysis   (HLO flops, bytes accessed)
  * collective bytes by op kind parsed from the post-SPMD HLO
  * roofline terms (compute/memory/collective seconds) and the dominant
    term, using TPU v5e constants.
"""

import argparse
import json
import re
import sys
import time
import traceback

import jax

from repro.launch.mesh import make_production_mesh
from repro.launch.cells import all_cells, build_cell
from repro.launch.roofline import (collective_bytes_from_hlo, roofline_report,
                                   model_flops)

ART_DIR = "artifacts/dryrun"


def _compile_plan(plan):
    jitted = jax.jit(plan.fn, in_shardings=plan.in_shardings,
                     out_shardings=plan.out_shardings)
    t0 = time.time()
    lowered = jitted.lower(*plan.args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    return compiled, t_lower, t_compile


def _costs_of(compiled):
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    coll = collective_bytes_from_hlo(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "wire": coll["total_wire_bytes"],
        "coll_detail": coll,
    }


def run_cell(arch_id: str, shape: str, mesh_kind: str, out_dir: str = ART_DIR,
             force: bool = False, variant: dict | None = None,
             tag_suffix: str = "") -> dict:
    """Full compile (memory proof) + cost measurement.

    XLA's cost analysis counts scanned loop bodies once, so for LM cells
    (layer-scan + accumulation-scan) the true per-step cost is recovered
    from two UNROLLED truncated compiles:

        delta    = cost(L=3) - cost(L=2)          # exact per-layer cost
        per_mb   = cost(L=2) + (L_full - 2) * delta
        per_step = accum * per_mb                 # train: accum microbatches

    (optimizer-update flops/bytes are over-multiplied by accum this way;
    the overcount is < 1% of the step and noted in DESIGN.md.)
    GNN/recsys cells have no scans — one compile measures truth directly.
    """
    os.makedirs(out_dir, exist_ok=True)
    tag = f"{arch_id}__{shape}__{mesh_kind}{tag_suffix}"
    path = os.path.join(out_dir, tag + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    if variant and variant.get("cache_shard") == "kv_heads":
        # decode-specific mesh: same 256 chips, factored so the 8 KV heads
        # shard evenly (16 data x 8 model x 2 seq)
        mesh = jax.make_mesh((16, 8, 2), ("data", "model", "seq2"))
    else:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    from repro.configs import get_arch
    family = get_arch(arch_id).family

    # ---- full compile: proves lowering+compile at scale, memory analysis
    plan = build_cell(arch_id, shape, mesh=mesh, variant=variant)
    compiled, t_lower, t_compile = _compile_plan(plan)
    mem = compiled.memory_analysis()
    full_costs = _costs_of(compiled)

    # ---- cost measurement
    if family == "lm":
        n_layers_full = get_arch(arch_id).build_cfg().n_layers
        accum = plan.meta.get("accum", 1)
        c2 = _costs_of(_compile_plan(
            build_cell(arch_id, shape, mesh=mesh, measure_layers=2,
                       variant=variant))[0])
        c3 = _costs_of(_compile_plan(
            build_cell(arch_id, shape, mesh=mesh, measure_layers=3,
                       variant=variant))[0])
        mult = accum if plan.kind == "train" else 1
        corrected = {}
        for key in ("flops", "bytes", "wire"):
            delta = max(c3[key] - c2[key], 0.0)
            corrected[key] = mult * (c2[key] + (n_layers_full - 2) * delta)
        measurement = {"L2": {k: c2[k] for k in ("flops", "bytes", "wire")},
                       "L3": {k: c3[k] for k in ("flops", "bytes", "wire")},
                       "extrapolated_layers": n_layers_full,
                       "accum_mult": mult}
    else:
        corrected = {k: full_costs[k] for k in ("flops", "bytes", "wire")}
        measurement = {"direct": True}

    n_dev = 512 if mesh_kind == "multi" else 256
    mf = model_flops(arch_id, shape, plan.meta)
    rep = roofline_report(
        flops_per_device=corrected["flops"],
        bytes_per_device=corrected["bytes"],
        collective_wire_bytes=corrected["wire"],
        n_devices=n_dev, model_flops_global=mf)

    record = {
        "arch": arch_id, "shape": shape, "mesh": mesh_kind,
        "variant": variant or {},
        "kind": plan.kind, "meta": plan.meta,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes":
                getattr(mem, "generated_code_size_in_bytes", None),
        },
        "cost_per_device": corrected,
        "cost_full_compile_raw": {k: full_costs[k]
                                  for k in ("flops", "bytes", "wire")},
        "collectives_full_raw": full_costs["coll_detail"],
        "measurement": measurement,
        "roofline": rep,
    }
    with open(path, "w") as f:
        json.dump(record, f, indent=1)
    return record


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi",
                                                       "both"])
    ap.add_argument("--cells", default=None, help="'all' for every cell")
    ap.add_argument("--out", default=ART_DIR)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args(argv)

    assert len(jax.devices()) == 512, "dry-run needs 512 placeholder devices"

    if args.cells == "all":
        todo = [(a, s, sk) for a, s, sk in all_cells()]
    else:
        assert args.arch and args.shape
        from repro.configs import get_arch
        todo = [(args.arch, args.shape, get_arch(args.arch).skip(args.shape))]

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    failures = []
    for arch_id, shape, skip in todo:
        if skip:
            print(f"SKIP {arch_id} x {shape}: {skip}", flush=True)
            tag_rec = {"arch": arch_id, "shape": shape, "skipped": skip}
            os.makedirs(args.out, exist_ok=True)
            for mk in meshes:
                with open(os.path.join(
                        args.out, f"{arch_id}__{shape}__{mk}.json"),
                        "w") as f:
                    json.dump(tag_rec, f)
            continue
        for mk in meshes:
            try:
                rec = run_cell(arch_id, shape, mk, out_dir=args.out,
                               force=args.force)
                r = rec["roofline"]
                print(f"OK {arch_id} x {shape} [{mk}] "
                      f"compile={rec.get('compile_s', '?')}s "
                      f"compute={r['compute_s']:.4f}s "
                      f"memory={r['memory_s']:.4f}s "
                      f"coll={r['collective_s']:.4f}s "
                      f"bound={r['bound']}", flush=True)
            except Exception as e:  # noqa: BLE001
                failures.append((arch_id, shape, mk, repr(e)))
                print(f"FAIL {arch_id} x {shape} [{mk}]: {e}", flush=True)
                traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} failures", flush=True)
        sys.exit(1)
    print("\nall requested cells passed", flush=True)


if __name__ == "__main__":
    main()
