"""Cell builders: (arch, shape, mesh) -> jit-able step + abstract args +
shardings.

A *cell* is one (architecture x input-shape) pair. ``build_cell`` returns
everything the dry-run (and the real launcher) needs:

    CellPlan(fn, args, in_shardings, out_shardings, meta)

``args`` are ShapeDtypeStructs (params included — nothing is allocated).
The same builders serve the smoke tests with ``reduced=True`` and
``mesh=None`` (no sharding, concrete arrays supplied by the caller).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import get_arch
from repro.configs.base import ArchDef

# repro.dist is an optional subsystem (sharding rules for multi-device
# meshes). Import lazily so unsharded (mesh=None) cell building — all the
# smoke tests need — works in environments without it.
try:
    from repro.dist.sharding import (GNN_RULES, LM_RULES, RECSYS_RULES,
                                     batch_axes, make_constrain, spec_for)
    _HAS_DIST = True
except ModuleNotFoundError:  # pragma: no cover - depends on environment
    _HAS_DIST = False
    GNN_RULES = LM_RULES = RECSYS_RULES = None

    def _missing_dist(*_a, **_k):
        raise ModuleNotFoundError(
            "repro.dist is required for sharded (mesh is not None) cells")

    batch_axes = make_constrain = spec_for = _missing_dist

from repro.models import gnn as gnn_mod
from repro.models import recsys as rec_mod
from repro.models import transformer as tf_mod
from repro.train.optimizer import AdamWConfig, abstract_adamw, init_adamw
from repro.train.train_loop import make_train_step

# per-arch optimizer settings (moment dtype matters for HBM at 405B)
_OPT = {
    "llama3-405b": AdamWConfig(lr=8e-5, moment_dtype=jnp.bfloat16),
    "mixtral-8x22b": AdamWConfig(lr=1e-4),
}
_DEFAULT_OPT = AdamWConfig()


@dataclasses.dataclass
class CellPlan:
    arch_id: str
    shape: str
    kind: str
    fn: Callable
    args: tuple                      # ShapeDtypeStructs (or concrete)
    in_shardings: Any
    out_shardings: Any
    meta: dict


def _dp(mesh):
    return batch_axes(mesh) if mesh is not None else ()


def _ns(mesh, *spec):
    return NamedSharding(mesh, P(*spec))


# --------------------------------------------------------------------- LM

def _divides(n, mesh, axes):
    size = math.prod(mesh.shape[a] for a in axes)
    return n % size == 0


def lm_param_spec(mesh, path_names, leaf, replicate_moe: bool = False) -> P:
    """Sharding rule for one LM param leaf, with divisibility fallbacks.

    Megatron TP on the model axis + FSDP on the (pod,data) axes:
      wq/wk/wv/w_gate/w_up/moe_gate/moe_up: model on last dim, FSDP on -2
      wo/w_down/moe_down:                   model on -2,      FSDP on last
      embed: vocab rows on model, d on FSDP; lm_head transposed rule
    """
    name = path_names[-1]
    fsdp = _dp(mesh)
    shape = leaf.shape

    def ok(dim, axes):
        return axes and _divides(shape[dim], mesh, axes)

    col = {"wq", "wk", "wv", "w_gate", "w_up", "moe_gate", "moe_up"}
    row = {"wo", "w_down", "moe_down"}
    if replicate_moe and name.startswith("moe_"):
        # fine-grained-MoE variant: weights replicated, dispatch buffers
        # sharded on capacity instead (moe_shard_c)
        return P(*([None] * len(shape)))
    spec = [None] * len(shape)
    if name in col or name in row:
        m_dim = len(shape) - 1 if name in col else len(shape) - 2
        f_dim = len(shape) - 2 if name in col else len(shape) - 1
        if ok(m_dim, ("model",)):
            spec[m_dim] = "model"
        if ok(f_dim, fsdp):
            spec[f_dim] = fsdp if len(fsdp) > 1 else fsdp[0]
    elif name == "embed":
        if ok(0, ("model",)):
            spec[0] = "model"
            if ok(1, fsdp):
                spec[1] = fsdp if len(fsdp) > 1 else fsdp[0]
        elif ok(1, ("model",)):
            spec[1] = "model"
    elif name == "lm_head":
        if ok(1, ("model",)):
            spec[1] = "model"
            if ok(0, fsdp):
                spec[0] = fsdp if len(fsdp) > 1 else fsdp[0]
        elif ok(0, ("model",)):
            spec[0] = "model"
    # norms, router, biases: replicated
    return P(*spec)


def _path_names(path):
    names = []
    for k in path:
        if hasattr(k, "key"):
            names.append(str(k.key))
        elif hasattr(k, "idx"):
            names.append(str(k.idx))
        else:
            names.append(str(k))
    return names


def lm_param_shardings(mesh, params_abs, replicate_moe: bool = False):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _ns(mesh, *lm_param_spec(
            mesh, _path_names(path), leaf, replicate_moe)),
        params_abs)


def opt_shardings(mesh, opt_abs, param_sh):
    """Optimizer state shards exactly like params; step is replicated."""
    return type(opt_abs)(
        step=_ns(mesh),
        m=jax.tree.map(lambda s: s, param_sh),
        v=jax.tree.map(lambda s: s, param_sh))


def _batch_shardings(mesh, specs: dict, rules) -> dict:
    logical = {
        "tokens": ("batch", None, None), "labels": ("batch", None, None),
        "nodes": ("nodes", None), "pos": ("nodes", None),
        "edge_src": ("edges",), "edge_dst": ("edges",),
        "edge_x": ("edges", None), "node_mask": ("nodes",),
        "edge_mask": ("edges",), "graph_id": ("nodes",),
        "targets": ("nodes", None), "graph_targets": (None,),
        "user_ids": ("batch", None, None), "item_ids": ("batch", None, None),
        "item_logq": ("batch",), "cand_embs": ("cands", None),
    }
    out = {}
    for k, v in specs.items():
        if k == "labels" and len(v.shape) == 1:      # gnn labels
            lg = ("nodes",)
        else:
            lg = logical.get(k, tuple([None] * len(v.shape)))
        lg = tuple(lg)[:len(v.shape)]
        lg = lg + (None,) * (len(v.shape) - len(lg))
        out[k] = NamedSharding(mesh, spec_for(mesh, v.shape, lg, rules))
    return out


def _lm_train_batch_shardings(mesh, specs):
    dp = _dp(mesh)
    dpa = dp if len(dp) > 1 else (dp[0] if dp else None)
    out = {}
    for k, v in specs.items():
        # (accum, microbatch, seq): shard microbatch over data axes
        spec = [None] * len(v.shape)
        size = math.prod(mesh.shape[a] for a in dp) if dp else 1
        if len(v.shape) >= 2 and v.shape[1] % size == 0 and size > 1:
            spec[1] = dpa
        out[k] = NamedSharding(mesh, P(*spec))
    return out


def build_lm_cell(arch: ArchDef, shape: str, mesh: Optional[Mesh],
                  reduced: bool = False, *,
                  measure_layers: Optional[int] = None,
                  variant: Optional[dict] = None) -> CellPlan:
    """``measure_layers``: build a cost-measurement variant — the model is
    truncated to that many UNROLLED layers and grad accumulation is
    disabled (batch = one microbatch). Used by the dry-run to recover true
    per-layer FLOPs/collectives (XLA cost analysis counts scanned loop
    bodies exactly once; see launch/dryrun.py).

    ``variant``: perf-experiment knobs. Model-config fields (e.g.
    ``seq_shard``) are applied with dataclasses.replace; ``cache_shard``
    selects the decode-cache sharding layout
    ("kv_seq" | "kv_heads" | "batch_model")."""
    import dataclasses as _dc
    variant = dict(variant or {})
    cache_shard = variant.pop("cache_shard", "kv_seq")
    constrain = make_constrain(mesh, LM_RULES) if mesh is not None else None
    cfg = arch.build_cfg(reduced=reduced, constrain=constrain)
    if variant:
        cfg = _dc.replace(cfg, **variant)
    if measure_layers is not None:
        # keep cfg.remat as configured: the measured FLOPs must include the
        # recompute the real (rematerialized) step performs, so that
        # MODEL_FLOPS / HLO_FLOPs exposes remat waste (§Roofline).
        cfg = _dc.replace(cfg, n_layers=measure_layers, scan_layers=False)
    kind = arch.step_kind(shape)
    specs = arch.input_specs(shape, reduced=reduced)
    if measure_layers is not None and kind in ("decode",):
        # cache leading dim must match truncated layer count
        for key in ("cache_k", "cache_v"):
            s = specs[key]
            specs[key] = jax.ShapeDtypeStruct((measure_layers,) + s.shape[1:],
                                              s.dtype)
    params_abs = tf_mod.abstract_params(cfg)
    opt_cfg = _OPT.get(arch.arch_id, _DEFAULT_OPT)
    meta = {"params_dense": cfg.params_dense, "params_active": cfg.params_active}

    if mesh is not None:
        p_sh = lm_param_shardings(mesh, params_abs,
                                  replicate_moe=cfg.moe_shard_c)
    else:
        p_sh = None

    if kind == "train":
        accum = arch.accum_steps.get(shape, 1) if not reduced else 2
        if measure_layers is not None:
            # one microbatch, no accumulation scan
            mb = {k: jax.ShapeDtypeStruct(v.shape[1:], v.dtype)
                  for k, v in specs.items()}
            specs = mb
            accum_eff = 1
        else:
            accum_eff = accum
        loss_fn = lambda p, b: tf_mod.lm_loss(p, b, cfg)
        step = make_train_step(loss_fn, opt_cfg, accum_steps=accum_eff,
                               grad_shardings=p_sh)
        opt_abs = abstract_adamw(params_abs, opt_cfg)
        args = (params_abs, opt_abs, specs)
        if mesh is not None:
            o_sh = opt_shardings(mesh, opt_abs, p_sh)
            if measure_layers is not None:
                b_sh = {k: NamedSharding(
                    mesh, spec_for(mesh, v.shape, ("batch", None),
                                   LM_RULES)) for k, v in specs.items()}
            else:
                b_sh = _lm_train_batch_shardings(mesh, specs)
            in_sh = (p_sh, o_sh, b_sh)
            out_sh = (p_sh, o_sh, _ns(mesh))
        else:
            in_sh = out_sh = None
        return CellPlan(arch.arch_id, shape, kind, step, args, in_sh, out_sh,
                        meta | {"accum": accum})

    if kind == "prefill":
        fn = lambda p, tokens: tf_mod.prefill(p, tokens, cfg)
        args = (params_abs, specs["tokens"])
        if mesh is not None:
            dp = _dp(mesh)
            dpa = dp if len(dp) > 1 else dp[0]
            tok_sh = _ns(mesh, dpa) if _divides(
                specs["tokens"].shape[0], mesh, dp) else _ns(mesh)
            B = specs["tokens"].shape[0]
            S = specs["tokens"].shape[1]
            Skv = min(S, cfg.window) if cfg.window else S
            cshape = (cfg.n_layers, B, Skv, cfg.n_kv_heads, cfg.d_head)
            cspec = spec_for(mesh, cshape,
                             (None, "batch", "kv_seq", None, None), LM_RULES)
            cache_sh = {"k": NamedSharding(mesh, cspec),
                        "v": NamedSharding(mesh, cspec), "pos": _ns(mesh)}
            logit_sh = NamedSharding(mesh, spec_for(
                mesh, (B, cfg.vocab), ("batch", "vocab"), LM_RULES))
            in_sh = (p_sh, tok_sh)
            out_sh = (cache_sh, logit_sh)
        else:
            in_sh = out_sh = None
        return CellPlan(arch.arch_id, shape, kind, fn, args, in_sh, out_sh,
                        meta)

    # decode
    if cfg.decode_paged:
        def fn(p, cache_k, cache_v, cache_pos, tokens):
            cache = {"k": cache_k, "v": cache_v, "pos": cache_pos}
            return tf_mod.serve_step_paged(p, cache, tokens, cfg)
    else:
        def fn(p, cache_k, cache_v, cache_pos, tokens):
            cache = {"k": cache_k, "v": cache_v, "pos": cache_pos}
            logits, new_cache = tf_mod.serve_step(p, cache, tokens, cfg)
            return logits, new_cache["k"], new_cache["v"], new_cache["pos"]

    args = (params_abs, specs["cache_k"], specs["cache_v"],
            specs["cache_pos"], specs["tokens"])
    if mesh is not None:
        cshape = specs["cache_k"].shape
        if cache_shard == "kv_heads":
            # requires the decode mesh (16, 8, 2)=("data","model","seq2"):
            # heads shard the 8-way model axis (even), the residual factor
            # 2 shards seq, and the cache update is (nearly) local
            seq2 = "seq2" if "seq2" in mesh.axis_names else None
            cspec = P(None, "data" if "data" in mesh.axis_names else None,
                      seq2, "model", None)
        elif cache_shard == "batch_model":
            cspec = P(None, ("data", "model") if cshape[1] % (
                mesh.shape["data"] * mesh.shape["model"]) == 0 else "data",
                None, None, None)
        else:
            cspec = spec_for(mesh, cshape,
                             (None, "batch", "kv_seq", None, None), LM_RULES)
        c_sh = NamedSharding(mesh, cspec)
        tok_sh = NamedSharding(mesh, spec_for(
            mesh, specs["tokens"].shape, ("batch", None), LM_RULES))
        logit_sh = NamedSharding(mesh, spec_for(
            mesh, (specs["tokens"].shape[0], cfg.vocab),
            ("batch", "vocab"), LM_RULES))
        if cfg.decode_paged:
            B = specs["tokens"].shape[0]
            new_kv_sh = NamedSharding(mesh, spec_for(
                mesh, (cfg.n_layers, B, 1, cfg.n_kv_heads, cfg.d_head),
                (None, "batch", None, None, None), LM_RULES))
            in_sh = (p_sh, c_sh, c_sh, _ns(mesh), tok_sh)
            out_sh = (logit_sh, new_kv_sh, new_kv_sh, _ns(mesh))
        else:
            in_sh = (p_sh, c_sh, c_sh, _ns(mesh), tok_sh)
            out_sh = (logit_sh, c_sh, c_sh, _ns(mesh))
    else:
        in_sh = out_sh = None
    return CellPlan(arch.arch_id, shape, kind, fn, args, in_sh, out_sh, meta)


# -------------------------------------------------------------------- GNN

def build_gnn_cell(arch: ArchDef, shape: str, mesh: Optional[Mesh],
                   reduced: bool = False) -> CellPlan:
    constrain = make_constrain(mesh, GNN_RULES) if mesh is not None else None
    cfg = arch.build_cfg(reduced=reduced, constrain=constrain, shape=shape)
    specs = arch.input_specs(shape, reduced=reduced)
    params_abs = jax.eval_shape(
        lambda: gnn_mod.init_gnn_params(jax.random.PRNGKey(0), cfg))
    opt_cfg = _DEFAULT_OPT
    loss_fn = lambda p, b: gnn_mod.gnn_loss(p, b, cfg)
    step = make_train_step(loss_fn, opt_cfg, accum_steps=1)
    opt_abs = abstract_adamw(params_abs, opt_cfg)
    args = (params_abs, opt_abs, specs)
    if mesh is not None:
        p_sh = jax.tree.map(lambda _: _ns(mesh), params_abs)   # replicated
        o_sh = opt_shardings(mesh, opt_abs, p_sh)
        b_sh = _batch_shardings(mesh, specs, GNN_RULES)
        in_sh = (p_sh, o_sh, b_sh)
        out_sh = (p_sh, o_sh, _ns(mesh))
    else:
        in_sh = out_sh = None
    return CellPlan(arch.arch_id, shape, "train", step, args, in_sh, out_sh,
                    {"d_hidden": cfg.d_hidden, "n_layers": cfg.n_layers})


# ------------------------------------------------------------------ recsys

def build_recsys_cell(arch: ArchDef, shape: str, mesh: Optional[Mesh],
                      reduced: bool = False) -> CellPlan:
    constrain = make_constrain(mesh, RECSYS_RULES) if mesh is not None else None
    cfg = arch.build_cfg(reduced=reduced, constrain=constrain)
    kind = arch.step_kind(shape)
    specs = arch.input_specs(shape, reduced=reduced)
    params_abs = jax.eval_shape(
        lambda: rec_mod.init_twotower_params(jax.random.PRNGKey(0), cfg))

    def table_spec(leaf, name):
        if name.endswith("table") and leaf.shape[0] % mesh.shape["model"] == 0:
            return _ns(mesh, "model", None)
        return _ns(mesh)

    if mesh is not None:
        p_sh = jax.tree_util.tree_map_with_path(
            lambda path, leaf: table_spec(leaf, _path_names(path)[0]),
            params_abs)
    else:
        p_sh = None

    if kind == "train":
        opt_cfg = _DEFAULT_OPT
        loss_fn = lambda p, b: rec_mod.twotower_loss(p, b, cfg)
        step = make_train_step(loss_fn, opt_cfg, accum_steps=1)
        opt_abs = abstract_adamw(params_abs, opt_cfg)
        args = (params_abs, opt_abs, specs)
        if mesh is not None:
            o_sh = opt_shardings(mesh, opt_abs, p_sh)
            b_sh = _batch_shardings(mesh, specs, RECSYS_RULES)
            in_sh = (p_sh, o_sh, b_sh)
            out_sh = (p_sh, o_sh, _ns(mesh))
        else:
            in_sh = out_sh = None
        return CellPlan(arch.arch_id, shape, kind, step, args, in_sh, out_sh,
                        {})
    if kind == "serve":
        fn = lambda p, b: rec_mod.score_batch(p, b, cfg)
        args = (params_abs, specs)
        if mesh is not None:
            b_sh = _batch_shardings(mesh, specs, RECSYS_RULES)
            out_spec = spec_for(mesh, (specs["user_ids"].shape[0],),
                                ("batch",), RECSYS_RULES)
            in_sh = (p_sh, b_sh)
            out_sh = NamedSharding(mesh, out_spec)
        else:
            in_sh = out_sh = None
        return CellPlan(arch.arch_id, shape, kind, fn, args, in_sh, out_sh,
                        {})
    # retrieve (top_k returns a list; normalize to tuple for out_shardings)
    fn = lambda p, b: tuple(rec_mod.retrieve(p, b, cfg, top_k=128))
    args = (params_abs, specs)
    if mesh is not None:
        b_sh = _batch_shardings(mesh, specs, RECSYS_RULES)
        in_sh = (p_sh, b_sh)
        out_sh = (_ns(mesh), _ns(mesh))
    else:
        in_sh = out_sh = None
    return CellPlan(arch.arch_id, shape, kind, fn, args, in_sh, out_sh, {})


# ----------------------------------------------------------------- entry

def build_cell(arch_id: str, shape: str, mesh: Optional[Mesh] = None,
               reduced: bool = False,
               measure_layers: Optional[int] = None,
               variant: Optional[dict] = None) -> CellPlan:
    arch = get_arch(arch_id)
    if arch.family == "lm":
        return build_lm_cell(arch, shape, mesh, reduced,
                             measure_layers=measure_layers, variant=variant)
    if arch.family == "gnn":
        return build_gnn_cell(arch, shape, mesh, reduced)
    if arch.family == "recsys":
        return build_recsys_cell(arch, shape, mesh, reduced)
    raise ValueError(arch.family)


def all_cells():
    """The 40 assigned (arch x shape) cells, with skip reasons."""
    from repro.configs import ARCH_IDS
    out = []
    for aid in ARCH_IDS:
        arch = get_arch(aid)
        for shape in arch.shapes:
            out.append((aid, shape, arch.skip(shape)))
    return out
