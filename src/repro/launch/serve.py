"""Serving launcher: batched decode loop for LM archs, scoring for recsys.

    PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x22b \
        --reduced --tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_arch
from repro.models import transformer as tf_mod
from repro.models import recsys as rec_mod


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt_len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    args = ap.parse_args(argv)

    arch = get_arch(args.arch)
    rng = np.random.default_rng(0)

    if arch.family == "lm":
        cfg = arch.build_cfg(reduced=args.reduced)
        params = tf_mod.init_params(jax.random.PRNGKey(0), cfg)
        prompts = jnp.asarray(
            rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)),
            jnp.int32)
        prefill_j = jax.jit(lambda p, t: tf_mod.prefill(p, t, cfg))
        decode_j = jax.jit(lambda p, c, t: tf_mod.serve_step(p, c, t, cfg))
        t0 = time.time()
        cache, logits = prefill_j(params, prompts)
        cache = dict(cache)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        n_out = 0
        for _ in range(args.tokens):
            logits, cache = decode_j(params, cache, tok)
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            n_out += args.batch
        jax.block_until_ready(logits)
        dt = time.time() - t0
        print(f"{args.arch}: served {n_out} tokens in {dt:.2f}s "
              f"({n_out / dt:.1f} tok/s incl. prefill)")
    elif arch.family == "recsys":
        cfg = arch.build_cfg(reduced=args.reduced)
        params = rec_mod.init_twotower_params(jax.random.PRNGKey(0), cfg)
        ids = (args.batch, cfg.n_fields, cfg.bag_size)
        batch = {"user_ids": jnp.asarray(rng.integers(-1, cfg.user_vocab,
                                                      ids), jnp.int32),
                 "item_ids": jnp.asarray(rng.integers(-1, cfg.item_vocab,
                                                      ids), jnp.int32)}
        score_j = jax.jit(lambda p, b: rec_mod.score_batch(p, b, cfg))
        t0 = time.time()
        s = score_j(params, batch)
        jax.block_until_ready(s)
        print(f"{args.arch}: scored {args.batch} pairs in "
              f"{(time.time() - t0) * 1e3:.1f} ms")
    else:
        raise SystemExit("gnn archs are trained, not served; use train.py")


if __name__ == "__main__":
    main()
