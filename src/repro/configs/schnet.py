"""schnet [gnn] n_interactions=3 d_hidden=64 rbf=300 cutoff=10 —
[arXiv:1706.08566; paper]."""
from .gnn_common import make_gnn_arch

ARCH = make_gnn_arch("schnet", arch="schnet", n_layers=3, d_hidden=64,
                     rbf=300, cutoff=10.0,
                     notes="continuous-filter convolutions over RBF(dist)")
