"""The paper's own workload: HYPE partitioning runs (not a neural arch).

Exposes the benchmark configurations used in EXPERIMENTS.md — dataset
generators at the paper's Table II scales and the algorithm parameter
grid (k, s, r, caching) of Figures 3-10.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class HypePaperConfig:
    datasets: tuple = ("github", "stackoverflow", "reddit")
    ks: tuple = (2, 4, 8, 16, 32, 64, 128)
    s: int = 10
    r: int = 2
    use_cache: bool = True
    methods: tuple = ("hype", "minmax_nb", "minmax_eb", "shp", "multilevel",
                      "random")


ARCH = HypePaperConfig()
