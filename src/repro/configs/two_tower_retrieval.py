"""two-tower-retrieval [recsys] embed_dim=256 tower_mlp=1024-512-256
interaction=dot — sampled-softmax retrieval [RecSys'19 (YouTube);
unverified].

Shapes:
  train_batch     batch=65,536               (training)
  serve_p99       batch=512                  (online inference)
  serve_bulk      batch=262,144              (offline scoring)
  retrieval_cand  batch=1 n_cand=1,000,000   (retrieval scoring)
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.recsys import TwoTowerConfig
from .base import ArchDef, ShapeSpec, sds

SHAPES = {
    "train_batch": ShapeSpec("train_batch", "train", {"batch": 65_536}),
    "serve_p99": ShapeSpec("serve_p99", "serve", {"batch": 512}),
    "serve_bulk": ShapeSpec("serve_bulk", "serve", {"batch": 262_144}),
    "retrieval_cand": ShapeSpec("retrieval_cand", "retrieve",
                                {"batch": 1, "n_cand": 1_048_576}),
}

_FULL = dict(embed_dim=256, tower_dims=(1024, 512, 256), n_fields=4,
             bag_size=8, user_vocab=16_777_216, item_vocab=16_777_216)
_RED = dict(embed_dim=16, tower_dims=(64, 32, 16), n_fields=4, bag_size=4,
            user_vocab=1024, item_vocab=1024)


def build_cfg(reduced: bool = False, constrain=None) -> TwoTowerConfig:
    kw = _RED if reduced else _FULL
    extra = {} if constrain is None else {"constrain": constrain}
    return TwoTowerConfig(name="two-tower-retrieval", **kw, **extra)


def input_specs(shape_name: str, reduced: bool = False):
    cfg = build_cfg(reduced)
    meta = SHAPES[shape_name].meta
    B = 32 if reduced else meta["batch"]
    ids = (B, cfg.n_fields, cfg.bag_size)
    if shape_name == "train_batch":
        return {"user_ids": sds(ids, jnp.int32),
                "item_ids": sds(ids, jnp.int32),
                "item_logq": sds((B,), jnp.float32)}
    if shape_name in ("serve_p99", "serve_bulk"):
        return {"user_ids": sds(ids, jnp.int32),
                "item_ids": sds(ids, jnp.int32)}
    n_cand = 2048 if reduced else meta["n_cand"]
    out_dim = cfg.tower_dims[-1]
    return {"user_ids": sds((B, cfg.n_fields, cfg.bag_size), jnp.int32),
            "cand_embs": sds((n_cand, out_dim), jnp.float32)}


ARCH = ArchDef(arch_id="two-tower-retrieval", family="recsys",
               build_cfg=build_cfg, shapes=SHAPES, input_specs=input_specs,
               notes="embedding tables are the HYPE placement target")
