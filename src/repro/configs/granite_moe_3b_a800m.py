"""granite-moe-3b-a800m [moe] 32L d_model=1536 24H (GQA kv=8) d_ff=512
vocab=49155, MoE 40 experts top-8 — [hf:ibm-granite/granite-3.0-1b-a400m-base;
hf]. (The assignment's structured field says 40e top-8; we follow it.)"""
from repro.models.moe import MoEConfig
from .lm_common import make_lm_arch

ARCH = make_lm_arch(
    "granite-moe-3b-a800m",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8,
    d_ff=512, vocab=49155,
    moe=MoEConfig(n_experts=40, top_k=8, capacity_factor=1.25),
    rope_theta=10_000.0,
    accum_steps={"train_4k": 2},
    notes="fine-grained MoE (40e top-8, tiny d_ff); 24 heads do not divide "
          "the 16-way model axis -> attention heads replicated (see "
          "DESIGN.md sharding fallbacks). Production deployment enables "
          "pad_vocab + moe_shard_c (EXPERIMENTS.md §Perf D: 3.8x less "
          "collective wire); the registry default stays paper-baseline "
          "so the §Roofline table remains the before picture.",
)
