"""meshgraphnet [gnn] n_layers=15 d_hidden=128 aggregator=sum mlp_layers=2 —
[arXiv:2010.03409; unverified]."""
from .gnn_common import make_gnn_arch

ARCH = make_gnn_arch("meshgraphnet", arch="meshgraphnet", n_layers=15,
                     d_hidden=128, aggregator="sum", mlp_layers=2,
                     notes="encode-process-decode with edge+node MLPs")
