"""Architecture registry: ``get_arch(arch_id)`` -> ArchDef.

Ten assigned architectures + the paper's own partitioning workload
(``hype_paper``). Each ArchDef exposes exact full-scale configs, reduced
smoke configs, per-shape input specs, and step builders. See base.py.
"""
from __future__ import annotations

import importlib

_MODULES = {
    "stablelm-3b": "repro.configs.stablelm_3b",
    "qwen3-8b": "repro.configs.qwen3_8b",
    "llama3-405b": "repro.configs.llama3_405b",
    "mixtral-8x22b": "repro.configs.mixtral_8x22b",
    "granite-moe-3b-a800m": "repro.configs.granite_moe_3b_a800m",
    "gatedgcn": "repro.configs.gatedgcn",
    "meshgraphnet": "repro.configs.meshgraphnet",
    "schnet": "repro.configs.schnet",
    "graphsage-reddit": "repro.configs.graphsage_reddit",
    "two-tower-retrieval": "repro.configs.two_tower_retrieval",
    "hype_paper": "repro.configs.hype_paper",
}

ARCH_IDS = [a for a in _MODULES if a != "hype_paper"]


def get_arch(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; choose from {list(_MODULES)}")
    return importlib.import_module(_MODULES[arch_id]).ARCH
