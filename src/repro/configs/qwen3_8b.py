"""qwen3-8b [dense] 36L d_model=4096 32H (GQA kv=8) d_ff=12288
vocab=151936 — qk_norm, GQA [hf:Qwen/Qwen3-8B; hf]."""
from .lm_common import make_lm_arch

ARCH = make_lm_arch(
    "qwen3-8b",
    n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=12288, vocab=151936,
    qk_norm=True, rope_theta=1_000_000.0,
    accum_steps={"train_4k": 2},
    notes="GQA kv=8; qk-norm per head",
)
