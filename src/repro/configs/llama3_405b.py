"""llama3-405b [dense] 126L d_model=16384 128H (GQA kv=8) d_ff=53248
vocab=128256 — GQA 128k vocab [arXiv:2407.21783; unverified]."""
from .lm_common import make_lm_arch

ARCH = make_lm_arch(
    "llama3-405b",
    n_layers=126, d_model=16384, n_heads=128, n_kv_heads=8,
    d_ff=53248, vocab=128256,
    rope_theta=500_000.0,
    accum_steps={"train_4k": 8},
    notes="largest assigned arch; bf16 optimizer moments (see DESIGN.md)",
)
