"""ArchDef: the uniform interface every architecture config implements.

An ArchDef carries:
  * ``build_cfg(reduced, constrain)``   — model config (exact numbers from
    the public source, or a tiny same-family config for CPU smoke tests);
  * ``shapes``                          — shape-name -> ShapeSpec;
  * ``input_specs(shape, reduced)``     — ShapeDtypeStruct stand-ins for
    every model input (global, unsharded logical shapes);
  * ``step_kind(shape)``                — train | prefill | decode | serve
    | retrieve (decode/serve lower serve_step, NOT train_step);
  * ``skip(shape)``                     — reason string if the (arch,shape)
    cell is skipped (e.g. long_500k on pure full-attention archs).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Mapping, Optional

import jax
import jax.numpy as jnp


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(x) for x in shape), dtype)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str                      # train|prefill|decode|serve|retrieve
    meta: Mapping                  # family-specific numbers


@dataclasses.dataclass(frozen=True)
class ArchDef:
    arch_id: str
    family: str                    # lm | gnn | recsys
    build_cfg: Callable            # (reduced, constrain) -> model config
    shapes: Mapping[str, ShapeSpec]
    input_specs: Callable          # (shape_name, reduced) -> dict of SDS
    skip: Callable = lambda shape: None
    # family knobs used by the launch harness
    accum_steps: Mapping[str, int] = dataclasses.field(default_factory=dict)
    notes: str = ""

    def step_kind(self, shape: str) -> str:
        return self.shapes[shape].kind


def round_up(x: int, mult: int) -> int:
    return int(math.ceil(x / mult) * mult)
