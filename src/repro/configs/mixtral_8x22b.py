"""mixtral-8x22b [moe] 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768, MoE 8 experts top-2, SWA — [arXiv:2401.04088; hf]."""
from repro.models.moe import MoEConfig
from .lm_common import make_lm_arch

ARCH = make_lm_arch(
    "mixtral-8x22b",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=16384, vocab=32768,
    window=4096,                       # sliding-window attention
    moe=MoEConfig(n_experts=8, top_k=2, capacity_factor=1.25),
    rope_theta=1_000_000.0,
    accum_steps={"train_4k": 4},
    notes="SWA window 4096 -> rolling KV cache; runs long_500k",
)
