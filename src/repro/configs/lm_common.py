"""Shared builders for the five LM architectures.

LM shape set (assigned):
  train_4k      seq 4,096   global_batch 256    (training)
  prefill_32k   seq 32,768  global_batch 32     (inference prefill)
  decode_32k    seq 32,768  global_batch 128    (one-token decode vs cache)
  long_500k     seq 524,288 global_batch 1      (long-context decode;
                 requires sub-quadratic attention -> only the SWA arch runs
                 it; pure full-attention archs record a skip)
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp

from repro.models.moe import MoEConfig
from repro.models.transformer import TransformerConfig
from .base import ArchDef, ShapeSpec, sds

LM_SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train",
                          {"seq": 4096, "batch": 256}),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill",
                             {"seq": 32768, "batch": 32}),
    "decode_32k": ShapeSpec("decode_32k", "decode",
                            {"seq": 32768, "batch": 128}),
    "long_500k": ShapeSpec("long_500k", "decode",
                           {"seq": 524288, "batch": 1}),
}


def lm_input_specs(cfg: TransformerConfig, shape_name: str, accum: int):
    meta = LM_SHAPES[shape_name].meta
    B, S = meta["batch"], meta["seq"]
    if shape_name == "train_4k":
        mb = B // accum
        return {
            "tokens": sds((accum, mb, S), jnp.int32),
            "labels": sds((accum, mb, S), jnp.int32),
        }
    if shape_name == "prefill_32k":
        return {"tokens": sds((B, S), jnp.int32)}
    # decode shapes: one new token against a seq-length cache
    Skv = min(S, cfg.window) if cfg.window else S
    return {
        "tokens": sds((B, 1), jnp.int32),
        "cache_k": sds((cfg.n_layers, B, Skv, cfg.n_kv_heads, cfg.d_head),
                       cfg.dtype),
        "cache_v": sds((cfg.n_layers, B, Skv, cfg.n_kv_heads, cfg.d_head),
                       cfg.dtype),
        "cache_pos": sds((), jnp.int32),
    }


def make_lm_arch(arch_id: str, *, n_layers: int, d_model: int, n_heads: int,
                 n_kv_heads: int, d_ff: int, vocab: int, qk_norm: bool = False,
                 window: Optional[int] = None, moe: Optional[MoEConfig] = None,
                 rope_theta: float = 500_000.0,
                 accum_steps: Optional[dict] = None,
                 notes: str = "") -> ArchDef:
    d_head = d_model // n_heads
    accum_steps = accum_steps or {"train_4k": 2}

    def build_cfg(reduced: bool = False, constrain=None) -> TransformerConfig:
        kw = dict(name=arch_id, qk_norm=qk_norm, rope_theta=rope_theta)
        if constrain is not None:
            kw["constrain"] = constrain
        if reduced:
            r_moe = None if moe is None else dataclasses.replace(
                moe, n_experts=min(moe.n_experts, 4),
                top_k=min(moe.top_k, 2))
            return TransformerConfig(
                n_layers=2, d_model=64,
                n_heads=4, n_kv_heads=max(1, 4 * n_kv_heads // n_heads),
                d_head=16, d_ff=128, vocab=512,
                window=(16 if window else None), moe=r_moe, remat=False,
                **kw)
        return TransformerConfig(
            n_layers=n_layers, d_model=d_model, n_heads=n_heads,
            n_kv_heads=n_kv_heads, d_head=d_head, d_ff=d_ff, vocab=vocab,
            window=window, moe=moe, **kw)

    def input_specs(shape_name: str, reduced: bool = False):
        cfg = build_cfg(reduced)
        if reduced:
            # tiny shapes for CPU smoke tests
            table = {
                "train_4k": {"tokens": sds((2, 2, 32), jnp.int32),
                             "labels": sds((2, 2, 32), jnp.int32)},
                "prefill_32k": {"tokens": sds((2, 64), jnp.int32)},
                "decode_32k": {
                    "tokens": sds((2, 1), jnp.int32),
                    "cache_k": sds((cfg.n_layers, 2,
                                    min(64, cfg.window or 64),
                                    cfg.n_kv_heads, cfg.d_head), cfg.dtype),
                    "cache_v": sds((cfg.n_layers, 2,
                                    min(64, cfg.window or 64),
                                    cfg.n_kv_heads, cfg.d_head), cfg.dtype),
                    "cache_pos": sds((), jnp.int32)},
            }
            table["long_500k"] = table["decode_32k"]
            return table[shape_name]
        return lm_input_specs(cfg, shape_name,
                              accum_steps.get(shape_name, 1))

    def skip(shape_name: str):
        if shape_name == "long_500k" and window is None:
            return ("full quadratic attention at 524k context is "
                    "infeasible (O(S^2) scores); arch has no sub-quadratic "
                    "mode — skipped per assignment note, see DESIGN.md")
        return None

    return ArchDef(arch_id=arch_id, family="lm", build_cfg=build_cfg,
                   shapes=LM_SHAPES, input_specs=input_specs, skip=skip,
                   accum_steps=accum_steps, notes=notes)
