"""gatedgcn [gnn] n_layers=16 d_hidden=70 aggregator=gated —
[arXiv:2003.00982; paper]."""
from .gnn_common import make_gnn_arch

ARCH = make_gnn_arch("gatedgcn", arch="gatedgcn", n_layers=16, d_hidden=70,
                     aggregator="gated",
                     notes="edge-gated aggregation; d=70 (benchmark config)")
