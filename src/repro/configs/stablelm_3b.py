"""stablelm-3b [dense] 32L d_model=2560 32H (GQA kv=32) d_ff=6912
vocab=50304 — [hf:stabilityai/stablelm-2-1_6b; unverified]."""
from .lm_common import make_lm_arch

ARCH = make_lm_arch(
    "stablelm-3b",
    n_layers=32, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=6912, vocab=50304,
    rope_theta=10_000.0,
    accum_steps={"train_4k": 2},
    notes="MHA (kv=32); SwiGLU",
)
