"""Shared builders for the four GNN architectures.

GNN shape set (assigned; identical across the four archs):
  full_graph_sm  n=2,708 e=10,556 d_feat=1,433      (full-batch, cora)
  minibatch_lg   n=232,965 e=114,615,892 batch=1,024 fanout 15-10
                 (the 114M-edge graph lives host-side in the sampler; the
                  lowered step consumes the *sampled* padded subgraph)
  ogb_products   n=2,449,029 e=61,859,140 d_feat=100 (full-batch-large)
  molecule       n=30 e=64 batch=128                 (batched small graphs)

Node/edge counts are padded up to multiples of 512 so every mesh shards
them evenly.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.gnn import GNNConfig
from .base import ArchDef, ShapeSpec, round_up, sds

# sampled-subgraph sizing for minibatch_lg: 1024 seeds, fanout 15 then 10
_MB_SEEDS = 1024
_MB_NODES = round_up(_MB_SEEDS * (1 + 15 + 15 * 10), 512)      # 170,496
_MB_EDGES = round_up(_MB_SEEDS * (15 + 15 * 10), 512)          # 169,472

GNN_SHAPES = {
    "full_graph_sm": ShapeSpec("full_graph_sm", "train", {
        "nodes": round_up(2708, 512), "edges": round_up(10556, 512),
        "d_feat": 1433, "n_classes": 7, "task": "node_class"}),
    "minibatch_lg": ShapeSpec("minibatch_lg", "train", {
        "nodes": _MB_NODES, "edges": _MB_EDGES,
        "d_feat": 602, "n_classes": 41, "task": "node_class",
        "graph_nodes": 232_965, "graph_edges": 114_615_892,
        "fanout": (15, 10), "batch_nodes": _MB_SEEDS}),
    "ogb_products": ShapeSpec("ogb_products", "train", {
        "nodes": round_up(2_449_029, 512), "edges": round_up(61_859_140, 512),
        "d_feat": 100, "n_classes": 47, "task": "node_class"}),
    "molecule": ShapeSpec("molecule", "train", {
        "nodes": round_up(30 * 128, 512), "edges": round_up(64 * 128, 512),
        "d_feat": 16, "n_classes": 2, "task": "graph_reg",
        "n_graphs": 128}),
}

_REDUCED = {"nodes": 256, "edges": 512, "d_feat": 24, "n_classes": 5,
            "task": "node_class", "n_graphs": 8}


def gnn_batch_specs(meta: dict, d_edge: int = 4):
    N, E = meta["nodes"], meta["edges"]
    specs = {
        "nodes": sds((N, meta["d_feat"]), jnp.float32),
        "pos": sds((N, 3), jnp.float32),
        "edge_src": sds((E,), jnp.int32),
        "edge_dst": sds((E,), jnp.int32),
        "edge_x": sds((E, d_edge), jnp.float32),
        "node_mask": sds((N,), jnp.bool_),
        "edge_mask": sds((E,), jnp.bool_),
        "graph_id": sds((N,), jnp.int32),
        "labels": sds((N,), jnp.int32),
        "targets": sds((N, meta["d_feat"]), jnp.float32),
        "graph_targets": sds((max(meta.get("n_graphs", 1), 1),), jnp.float32),
    }
    return specs


def make_gnn_arch(arch_id: str, *, arch: str, n_layers: int, d_hidden: int,
                  aggregator: str = "sum", mlp_layers: int = 2,
                  rbf: int = 300, cutoff: float = 10.0,
                  notes: str = "") -> ArchDef:

    def build_cfg(reduced: bool = False, constrain=None,
                  shape: str = "full_graph_sm") -> GNNConfig:
        meta = _REDUCED if reduced else GNN_SHAPES[shape].meta
        kw = {} if constrain is None else {"constrain": constrain}
        return GNNConfig(
            name=arch_id, arch=arch,
            n_layers=2 if reduced else n_layers,
            d_hidden=16 if reduced else d_hidden,
            d_in=meta["d_feat"], d_edge_in=4,
            n_classes=meta["n_classes"],
            aggregator=aggregator, mlp_layers=mlp_layers,
            rbf=16 if reduced else rbf, cutoff=cutoff,
            task=meta["task"], **kw)

    def input_specs(shape_name: str, reduced: bool = False):
        meta = _REDUCED if reduced else GNN_SHAPES[shape_name].meta
        return gnn_batch_specs(meta)

    return ArchDef(arch_id=arch_id, family="gnn", build_cfg=build_cfg,
                   shapes=GNN_SHAPES, input_specs=input_specs, notes=notes)
