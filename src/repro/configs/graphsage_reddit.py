"""graphsage-reddit [gnn] n_layers=2 d_hidden=128 aggregator=mean
sample_sizes=25-10 — [arXiv:1706.02216; paper].

(The assigned minibatch shape uses fanout 15-10; the arch's own paper
config samples 25-10 — the sampler supports both, the assigned shape
wins for the dry-run cells.)"""
from .gnn_common import make_gnn_arch

ARCH = make_gnn_arch("graphsage-reddit", arch="graphsage", n_layers=2,
                     d_hidden=128, aggregator="mean",
                     notes="mean aggregator + l2-normalized layers")
