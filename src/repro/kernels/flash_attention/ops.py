"""Jitted public wrapper for the flash-attention kernel.

``interpret`` defaults to ``_compat.pallas_interpret()`` — True off-TPU
(so the same call sites run, slowly but correctly, on CPU), overridable
either way via ``REPRO_PALLAS_INTERPRET``; on TPU the compiled kernel
path is used.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels._compat import pallas_interpret

from .kernel import flash_attention_fwd


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None, block_q: int = 128,
                    block_k: int = 128, interpret: Optional[bool] = None):
    if interpret is None:    # resolved pre-jit: `interpret` is static,
        # so an in-trace default would freeze the env override
        interpret = pallas_interpret()
    return _flash_attention(q, k, v, causal=causal, window=window,
                            block_q=block_q, block_k=block_k,
                            interpret=interpret)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def _flash_attention(q, k, v, *, causal: bool, window: Optional[int],
                     block_q: int, block_k: int, interpret: bool):
    return flash_attention_fwd(q, k, v, causal=causal, window=window,
                               block_q=block_q, block_k=block_k,
                               interpret=interpret)
