"""Jitted public wrapper for the flash-attention kernel.

``interpret`` defaults to True off-TPU so the same call sites run (slowly
but correctly) on CPU; on TPU the compiled kernel path is used.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax

from .kernel import flash_attention_fwd


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None, block_q: int = 128,
                    block_k: int = 128, interpret: Optional[bool] = None):
    if interpret is None:
        interpret = not _on_tpu()
    return flash_attention_fwd(q, k, v, causal=causal, window=window,
                               block_q=block_q, block_k=block_k,
                               interpret=interpret)
