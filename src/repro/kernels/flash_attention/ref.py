"""Pure-jnp oracle for flash attention (causal / sliding-window / GQA)."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import jax


def attention_ref(q, k, v, *, causal: bool = True,
                  window: Optional[int] = None):
    """q: (B, S, Hq, D); k/v: (B, S, Hkv, D). Returns (B, S, Hq, D).

    Hq must be a multiple of Hkv (GQA). Softmax in fp32.
    """
    B, S, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, S, Hkv, G, D)
    scores = jnp.einsum("bshgd,bthd->bhgst", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / jnp.sqrt(D)
    i = jnp.arange(S)[:, None]
    j = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= j <= i
    if window is not None:
        mask &= j > i - window
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgst,bthd->bshgd", probs, v.astype(jnp.float32))
    return out.reshape(B, S, Hq, D).astype(q.dtype)
