from .ops import flash_attention
