"""Flash attention forward kernel (Pallas TPU).

Block-wise online softmax (Dao et al.) adapted to the TPU memory
hierarchy: Q/K/V tiles are staged HBM->VMEM by BlockSpec; the MXU consumes
(block_q x d) @ (d x block_k) tiles; running max/denominator/accumulator
live in VMEM scratch that persists across the innermost ("arbitrary")
grid dimension. Causal and sliding-window masks are applied in-kernel;
fully-masked K blocks are skipped with ``pl.when`` (this is what makes
SWA sub-quadratic on long contexts).

Grid: (B, Hq, nq, nk) — nk is the sequential dimension.
GQA: the K/V index map folds q-head -> kv-head (h // group).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401

from repro.kernels._compat import CompilerParams as _CompilerParams

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, block_q: int, block_k: int, seq_len: int,
                  causal: bool, window: Optional[int]):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = iq * block_q
    k_start = ik * block_k

    # block-level skip: with causal masking K blocks strictly above the
    # diagonal contribute nothing; with a window, blocks entirely below
    # (q_start - window) are dead too.
    live = jnp.full((), True)
    if causal:
        live = jnp.logical_and(live, k_start <= q_start + block_q - 1)
    if window is not None:
        live = jnp.logical_and(live,
                               k_start + block_k - 1 > q_start - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # (block_q, d)
        k = k_ref[0, 0].astype(jnp.float32)          # (block_k, d)
        v = v_ref[0, 0].astype(jnp.float32)          # (block_k, d)
        s = (q @ k.T) * scale                        # (block_q, block_k)

        qi = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                (block_q, block_k), 0)
        kj = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                (block_q, block_k), 1)
        mask = kj < seq_len
        if causal:
            mask &= kj <= qi
        if window is not None:
            mask &= kj > qi - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                          # (block_q, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + p @ v
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None, block_q: int = 128,
                        block_k: int = 128, interpret: bool = False):
    """q: (B, S, Hq, D) -> (B, S, Hq, D); k/v: (B, S, Hkv, D)."""
    B, S, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0, "pad seq to block multiple"
    nq, nk = S // block_q, S // block_k
    scale = 1.0 / (D ** 0.5)

    # layout: (B, H, S, D) blocks
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(
        _flash_kernel, scale=scale, block_q=block_q, block_k=block_k,
        seq_len=S, causal=causal, window=window)

    out = pl.pallas_call(
        kernel,
        grid=(B, Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D),
                         lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, iq, ik: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, iq, ik: (b, h // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max
            pltpu.VMEM((block_q, 1), jnp.float32),   # running denom
            pltpu.VMEM((block_q, D), jnp.float32),   # output accumulator
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
