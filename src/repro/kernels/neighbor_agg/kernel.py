"""Fused neighbor-gather + mean + projection kernel (Pallas TPU).

The sampled-GNN hot path (GraphSAGE minibatch regime): for each seed, mean
its K sampled neighbors' features and project. Same scalar-prefetch DMA
pattern as embedding_bag — the neighbor index matrix is prefetched so
BlockSpec index maps can stream exactly the needed feature rows
HBM->VMEM — then the per-seed mean is fed to the MXU against a
VMEM-resident (D, F) weight tile, fusing gather + reduce + GEMM in one
kernel (the FusedMM insight adapted to TPU: no materialized (B, K, D)
gather buffer in HBM).

Grid: (B, K) with the K dimension sequential; W stays resident.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401

from repro.kernels._compat import CompilerParams as _CompilerParams


def _agg_kernel(nbrs_ref, row_ref, w_ref, out_ref, acc_ref, cnt_ref):
    b = pl.program_id(0)
    j = pl.program_id(1)
    K = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    valid = nbrs_ref[b, j] >= 0

    @pl.when(valid)
    def _acc():
        acc_ref[...] += row_ref[...].astype(jnp.float32)
        cnt_ref[...] += 1

    @pl.when(j == K - 1)
    def _fin():
        denom = jnp.maximum(cnt_ref[0, 0], 1).astype(jnp.float32)
        mean = acc_ref[...] / denom                      # (1, D)
        out_ref[...] = (mean @ w_ref[...].astype(jnp.float32)
                        ).astype(out_ref.dtype)


def neighbor_agg_kernel(x, nbrs, w, *, interpret: bool = False):
    """x: (N, D); nbrs: (B, K); w: (D, F) -> (B, F)."""
    N, D = x.shape
    B, K = nbrs.shape
    F = w.shape[1]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, K),
        in_specs=[
            pl.BlockSpec((1, D),
                         lambda b, j, nbrs_ref: (
                             jnp.maximum(nbrs_ref[b, j], 0), 0)),
            pl.BlockSpec((D, F), lambda b, j, nbrs_ref: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, F), lambda b, j, nbrs_ref: (b, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, D), jnp.float32),
            pltpu.VMEM((1, 1), jnp.int32),
        ],
    )
    return pl.pallas_call(
        _agg_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, F), x.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(nbrs, x, w)
