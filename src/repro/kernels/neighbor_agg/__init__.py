from .ops import neighbor_agg
