"""Oracle for fused fixed-fanout neighbor aggregation + projection."""
from __future__ import annotations

import jax.numpy as jnp


def neighbor_agg_ref(x, nbrs, w):
    """x: (N, D); nbrs: (B, K) int32 (-1 pad); w: (D, F) -> (B, F).

    mean over valid neighbors of x[nbr] then @ w (GraphSAGE-style).
    """
    valid = nbrs >= 0
    rows = jnp.take(x, jnp.where(valid, nbrs, 0), axis=0)
    rows = jnp.where(valid[..., None], rows, 0)
    cnt = jnp.maximum(valid.sum(-1, keepdims=True), 1)
    mean = rows.sum(1) / cnt.astype(x.dtype)
    return (mean @ w).astype(x.dtype)
