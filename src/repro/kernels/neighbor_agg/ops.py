"""Jitted wrapper for the fused neighbor-aggregation kernel."""
from __future__ import annotations

import functools

import jax

from .kernel import neighbor_agg_kernel


@functools.partial(jax.jit, static_argnames=("interpret",))
def neighbor_agg(x, nbrs, w, *, interpret=None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return neighbor_agg_kernel(x, nbrs, w, interpret=interpret)
