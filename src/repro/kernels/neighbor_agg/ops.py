"""Jitted wrapper for the fused neighbor-aggregation kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels._compat import pallas_interpret

from .kernel import neighbor_agg_kernel


def neighbor_agg(x, nbrs, w, *, interpret=None):
    if interpret is None:    # resolved pre-jit: `interpret` is static,
        # so an in-trace default would freeze the env override
        interpret = pallas_interpret()
    return _neighbor_agg(x, nbrs, w, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _neighbor_agg(x, nbrs, w, *, interpret: bool):
    return neighbor_agg_kernel(x, nbrs, w, interpret=interpret)
