"""Jitted wrapper for the k-way move-gain kernel (auto-pad, auto-interpret)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels._compat import pallas_interpret

from .kernel import kway_gains_kernel


def kway_gains(parts, own, *, k: int, tile_b: int = 256, interpret=None):
    """Move gains for a batch of boundary vertices.

    parts: (B, L) int32 neighbor-partition tiles (-1 pad); own: (B,)
    int32 current partitions (-1 for pad rows). Returns (B, k) float32
    gains; ``gain[b, own[b]] == 0`` and pad rows are all-zero. The
    interpret default is resolved OUTSIDE the jit boundary (see
    ``hype_score.ops``): ``interpret`` is a static argname, so resolving
    it inside would freeze the env override at first trace.
    """
    if interpret is None:
        interpret = pallas_interpret()
    return _kway_gains(parts, own, k=k, tile_b=tile_b,
                       interpret=interpret)


@functools.partial(jax.jit, static_argnames=("k", "tile_b", "interpret"))
def _kway_gains(parts, own, *, k: int, tile_b: int, interpret: bool):
    B = parts.shape[0]
    tile = min(tile_b, max(8, B))
    pad = (-B) % tile
    if pad:
        parts = jnp.pad(parts, ((0, pad), (0, 0)), constant_values=-1)
        own = jnp.pad(own, ((0, pad),), constant_values=-1)
    out = kway_gains_kernel(parts, own, k=k, tile_b=tile,
                            interpret=interpret)
    return out[:B]
