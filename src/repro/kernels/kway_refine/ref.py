"""Oracle for the k-way move-gain kernel (numpy, exact).

gain[b, q] = #(parts[b, :] == q) - #(parts[b, :] == own[b]) — the
connectivity gain of moving row b's vertex to partition q, over its
padded neighbor-partition list. Pad lanes (-1) and pad rows (own = -1)
match no partition id, so their contributions are zero.
"""
from __future__ import annotations

import numpy as np


def kway_gains_ref(parts: np.ndarray, own: np.ndarray,
                   k: int) -> np.ndarray:
    """parts: (B, L) int32 (-1 pad); own: (B,) int32. Returns (B, k) f32."""
    parts = np.asarray(parts)
    own = np.asarray(own)
    cnt = (parts[:, None, :] == np.arange(k)[None, :, None]).sum(axis=2)
    cnt_own = ((parts == own[:, None]) & (parts >= 0)).sum(axis=1)
    return (cnt - cnt_own[:, None]).astype(np.float32)
