from .ops import kway_gains
