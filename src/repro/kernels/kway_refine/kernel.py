"""K-way move-gain kernel for the refinement subsystem (Pallas TPU).

Post-pass refinement (DESIGN.md §4e) screens every boundary vertex for
a profitable partition move. The screening score is the connectivity
gain over the vertex's *neighborhood* (the same unique-neighbor lists
the ``hype_score`` kernel tiles): for a vertex v in partition p,

    gain[v, q] = #(N(v) in q) - #(N(v) in p)

— how many more neighbors v would sit with after a move p -> q. Like
the scoring kernel, the tile is a dense (TB, L) block in VMEM, but the
rows hold the neighbors' *partition ids* (gathered on device against
the live assignment, -1 padded) instead of vertex ids, and the compare
loop runs over the k static partition ids instead of the s fringe
slots:

    cnt[b, q] = #(parts[b, :] == q)          one (TB, L) compare per q
    gain[b, q] = cnt[b, q] - cnt[b, own[b]]

No gather, no histogram scatter — k broadcast-compares + reductions per
tile, the same VPU shape as ``_score_kernel``. Pad rows (own = -1) and
pad lanes (parts = -1) never match a real partition id, so their gains
are all zero and the driver's positive-gain filter drops them for free.

The exact k-1 delta of a move needs per-hyperedge pin counts, which the
neighborhood image cannot provide; the driver verifies the screened
winners' exact gains on host before admitting any move (DESIGN.md §4e),
so this kernel only has to *rank* candidates, cheaply, for all of them.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401

from repro.kernels._compat import CompilerParams as _CompilerParams


def _gain_kernel(own_ref, parts_ref, out_ref, *, k: int):
    parts = parts_ref[...]                    # (TB, L) neighbor partitions
    own = own_ref[...]                        # (TB, 1) the row's own part
    # the -1 pad lanes of a -1 pad ROW would match own == -1; mask them
    # so pad rows count zero everywhere (real q ids never match a pad)
    cnt_own = jnp.logical_and(parts == own, parts >= 0).sum(axis=1)
    cols = []
    for q in range(k):                        # k is a small static constant
        cnt_q = (parts == q).sum(axis=1)
        cols.append(cnt_q - cnt_own)
    out_ref[...] = jnp.stack(cols, axis=1).astype(jnp.float32)


def kway_gains_kernel(parts, own, *, k: int, tile_b: int = 256,
                      interpret: bool = False):
    """parts: (B, L) int32 (-1 pad); own: (B,) int32 (-1 = pad row).

    Returns (B, k) float32 move gains; column ``own[b]`` is 0 by
    construction.
    """
    B, L = parts.shape
    tile_b = min(tile_b, B)
    assert B % tile_b == 0, "pad B to a tile multiple"
    out = pl.pallas_call(
        functools.partial(_gain_kernel, k=k),
        grid=(B // tile_b,),
        in_specs=[
            pl.BlockSpec((tile_b, 1), lambda i: (i, 0)),
            pl.BlockSpec((tile_b, L), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tile_b, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, k), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(own[:, None], parts)
    return out
