"""Jitted wrapper for the EmbeddingBag kernel."""
from __future__ import annotations

import functools

import jax

from .kernel import embedding_bag_kernel


@functools.partial(jax.jit, static_argnames=("combine", "interpret"))
def embedding_bag(table, ids, *, combine: str = "mean", interpret=None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return embedding_bag_kernel(table, ids, combine=combine,
                                interpret=interpret)
