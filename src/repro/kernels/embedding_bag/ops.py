"""Jitted wrapper for the EmbeddingBag kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels._compat import pallas_interpret

from .kernel import embedding_bag_kernel


def embedding_bag(table, ids, *, combine: str = "mean", interpret=None):
    if interpret is None:    # resolved pre-jit: `interpret` is static,
        # so an in-trace default would freeze the env override
        interpret = pallas_interpret()
    return _embedding_bag(table, ids, combine=combine,
                          interpret=interpret)


@functools.partial(jax.jit, static_argnames=("combine", "interpret"))
def _embedding_bag(table, ids, *, combine: str, interpret: bool):
    return embedding_bag_kernel(table, ids, combine=combine,
                                interpret=interpret)
