"""Oracle for EmbeddingBag (sum/mean over a padded multi-hot bag)."""
from __future__ import annotations

import jax.numpy as jnp


def embedding_bag_ref(table, ids, combine: str = "mean"):
    """table: (V, D); ids: (B, bag) int32 with -1 padding -> (B, D)."""
    valid = ids >= 0
    vecs = jnp.take(table, jnp.where(valid, ids, 0), axis=0)
    vecs = jnp.where(valid[..., None], vecs, 0)
    out = vecs.sum(axis=1)
    if combine == "mean":
        cnt = jnp.maximum(valid.sum(axis=1, keepdims=True), 1)
        out = out / cnt.astype(out.dtype)
    return out.astype(table.dtype)
