from .ops import embedding_bag
