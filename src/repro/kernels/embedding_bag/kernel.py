"""EmbeddingBag gather-reduce kernel (Pallas TPU, scalar-prefetch DMA).

The recsys hot path: huge table in HBM, ragged multi-hot ids per example.
TPU adaptation: ids are *scalar-prefetched* so the BlockSpec index_map can
schedule the HBM->VMEM DMA of exactly the rows the bag needs (the Pallas
embedding pattern) — no host gather, no one-hot matmul. The grid walks
(example, bag-slot); a VMEM fp32 accumulator carries the partial sum
across the bag dimension and the mean lands in the output row on the last
slot. Padded slots (-1) are skipped via ``pl.when`` but still DMA row 0 —
the index map must return a valid row; the accumulate is masked.

This kernel is the fast path behind models/recsys.embedding_bag.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401

from repro.kernels._compat import CompilerParams as _CompilerParams


def _bag_kernel(ids_ref, row_ref, out_ref, acc_ref, cnt_ref, *,
                combine: str):
    b = pl.program_id(0)
    j = pl.program_id(1)
    bag = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    valid = ids_ref[b, j] >= 0

    @pl.when(valid)
    def _acc():
        acc_ref[...] += row_ref[...].astype(jnp.float32)
        cnt_ref[...] += 1

    @pl.when(j == bag - 1)
    def _fin():
        total = acc_ref[...]
        if combine == "mean":
            denom = jnp.maximum(cnt_ref[0, 0], 1).astype(jnp.float32)
            total = total / denom
        out_ref[...] = total.astype(out_ref.dtype)


def embedding_bag_kernel(table, ids, *, combine: str = "mean",
                         interpret: bool = False):
    """table: (V, D); ids: (B, bag) -> (B, D)."""
    V, D = table.shape
    B, bag = ids.shape

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, bag),
        in_specs=[
            pl.BlockSpec((1, D),
                         lambda b, j, ids_ref: (
                             jnp.maximum(ids_ref[b, j], 0), 0)),
        ],
        out_specs=pl.BlockSpec((1, D), lambda b, j, ids_ref: (b, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, D), jnp.float32),
            pltpu.VMEM((1, 1), jnp.int32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_bag_kernel, combine=combine),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, D), table.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(ids, table)
