"""Pallas API compatibility shims shared by all kernels."""
import functools
import os

from jax.experimental.pallas import tpu as pltpu

# jax >= 0.5 renamed TPUCompilerParams -> CompilerParams
CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")

_TRUTHY = ("1", "true", "yes", "on")
_FALSY = ("0", "false", "no", "off")


def pallas_interpret() -> bool:
    """Single source of truth for Pallas interpret-mode selection.

    Default: interpret everywhere except on a real TPU backend (the
    kernels compile only there; interpret mode is the correct-but-slow
    path on CPU/GPU). The ``REPRO_PALLAS_INTERPRET`` env var overrides
    either way — ``1/true/yes/on`` forces interpret mode (e.g. to debug
    a kernel on TPU), ``0/false/no/off`` forces the compiled path (e.g.
    to exercise GPU/compiled-CPU lowering in CI) — so benchmarks and CI
    can pin the mode without touching call sites. Read per call, not
    cached: tests flip the env var at runtime. Callers must do the
    same — re-evaluate at every kernel invocation rather than stashing
    the value in long-lived engine state (the superstep engines expose
    it as a property for exactly this reason).
    """
    env = os.environ.get("REPRO_PALLAS_INTERPRET", "").strip().lower()
    if env in _TRUTHY:
        return True
    if env in _FALSY:
        return False
    import jax
    return jax.default_backend() != "tpu"


@functools.lru_cache(maxsize=1)
def enable_compile_cache() -> str | None:
    """Opt into JAX's persistent compilation cache via env knob.

    ``REPRO_COMPILE_CACHE`` names a directory to store compiled
    executables across processes; unset or falsy leaves caching off.
    The repro engines retrace identical while_loop/kernel programs on
    every cold start — for the device-resident loop that single XLA
    compile dominates small-graph wall time — so benchmarks and CI set
    this to amortise it. Min compile-time / entry-size thresholds are
    zeroed so the many small Pallas kernels qualify, not just the
    megakernel. Idempotent (cached); returns the directory in use, or
    ``None`` when disabled. Safe on jax builds without the persistent
    cache: config failures disable silently rather than break the run.
    """
    path = os.environ.get("REPRO_COMPILE_CACHE", "").strip()
    if not path or path.lower() in _FALSY:
        return None
    import jax
    try:
        jax.config.update("jax_compilation_cache_dir", path)
        jax.config.update("jax_persistent_cache_min_compile_time_secs", 0)
        jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
    except Exception:
        return None
    return path
