"""Pallas API compatibility shims shared by all kernels."""
import os

from jax.experimental.pallas import tpu as pltpu

# jax >= 0.5 renamed TPUCompilerParams -> CompilerParams
CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")

_TRUTHY = ("1", "true", "yes", "on")
_FALSY = ("0", "false", "no", "off")


def pallas_interpret() -> bool:
    """Single source of truth for Pallas interpret-mode selection.

    Default: interpret everywhere except on a real TPU backend (the
    kernels compile only there; interpret mode is the correct-but-slow
    path on CPU/GPU). The ``REPRO_PALLAS_INTERPRET`` env var overrides
    either way — ``1/true/yes/on`` forces interpret mode (e.g. to debug
    a kernel on TPU), ``0/false/no/off`` forces the compiled path (e.g.
    to exercise GPU/compiled-CPU lowering in CI) — so benchmarks and CI
    can pin the mode without touching call sites. Read per call, not
    cached: tests flip the env var at runtime. Callers must do the
    same — re-evaluate at every kernel invocation rather than stashing
    the value in long-lived engine state (the superstep engines expose
    it as a property for exactly this reason).
    """
    env = os.environ.get("REPRO_PALLAS_INTERPRET", "").strip().lower()
    if env in _TRUTHY:
        return True
    if env in _FALSY:
        return False
    import jax
    return jax.default_backend() != "tpu"
