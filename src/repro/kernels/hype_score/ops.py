"""Jitted wrapper for the HYPE scoring kernel (auto-pad, auto-interpret)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels._compat import pallas_interpret

from .kernel import hype_score_select_kernel, hype_scores_kernel


def hype_scores(nbrs, fringe, *, tile_b: int = 256, interpret=None):
    # resolve the interpret default OUTSIDE the jit boundary: `interpret`
    # is a static argname, so resolving it inside would freeze the env
    # override at first trace (jit would cache on the literal None)
    if interpret is None:
        interpret = pallas_interpret()
    return _hype_scores(nbrs, fringe, tile_b=tile_b, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("tile_b", "interpret"))
def _hype_scores(nbrs, fringe, *, tile_b: int, interpret: bool):
    B = nbrs.shape[0]
    tile = min(tile_b, max(8, B))
    pad = (-B) % tile
    if pad:
        nbrs = jnp.pad(nbrs, ((0, pad), (0, 0)), constant_values=-1)
    out = hype_scores_kernel(nbrs, fringe, tile_b=tile, interpret=interpret)
    return out[:B]


def hype_score_select_shard(nbrs_local, fringe, bias, prev, *,
                            select_k: int, shard_offset, tile_g: int = 8,
                            interpret=None):
    """Fused score + select for one *phase-group shard* of a superstep.

    The mesh-sharded engine stacks all ``G`` phases' per-superstep arrays
    globally but each device only gathers and scores its own contiguous
    group of ``gL = nbrs_local.shape[0]`` phases. This wrapper keeps the
    per-shard offset convention in one place: ``fringe``/``bias``/``prev``
    are the **global** ``(G, ...)`` stacked arrays, ``nbrs_local`` is the
    shard's already-gathered ``(gL, R, L)`` tile, and ``shard_offset`` is
    the shard's first global phase id — typically the traced value
    ``jax.lax.axis_index(axis) * gL`` under ``shard_map``. Returns the
    same ``(scores, sel_idx, sel_val)`` triple as ``hype_score_select``,
    restricted to the shard's ``gL`` phases.
    """
    gL = nbrs_local.shape[0]
    fringe_l = jax.lax.dynamic_slice_in_dim(fringe, shard_offset, gL, 0)
    bias_l = jax.lax.dynamic_slice_in_dim(bias, shard_offset, gL, 0)
    prev_l = jax.lax.dynamic_slice_in_dim(prev, shard_offset, gL, 0)
    return hype_score_select(nbrs_local, fringe_l, bias_l, prev_l,
                             select_k=select_k, tile_g=tile_g,
                             interpret=interpret)


def hype_score_select(nbrs, fringe, bias, prev, *, select_k: int,
                      tile_g: int = 8, interpret=None,
                      with_remaining: bool = False):
    """Fused score + per-phase top-``select_k`` selection (auto-interpret).

    nbrs: (G, R, L) int32 stacked phase tiles; fringe: (G, s) int32;
    bias: (G, R) float32 additive row bias; prev: (G, P) float32 held
    pool scores. The phase count is padded to a ``tile_g`` multiple for
    the kernel grid. Returns ``(scores (G, R), sel_idx (G, select_k),
    sel_val (G, select_k))``; sel_idx < R points at fresh rows, >= R at
    pool slot ``idx - R``. With ``with_remaining`` a fourth array rides
    along: remaining (G,) int32 — real candidate slots left per phase
    after selection, the refill-trigger flag the device-resident loop
    reads instead of asking the host. See
    ``kernel.hype_score_select_kernel``.
    """
    if interpret is None:    # resolved pre-jit; see hype_scores
        interpret = pallas_interpret()
    return _hype_score_select(nbrs, fringe, bias, prev,
                              select_k=select_k, tile_g=tile_g,
                              interpret=interpret,
                              with_remaining=with_remaining)


@functools.partial(jax.jit, static_argnames=("select_k", "tile_g",
                                             "interpret",
                                             "with_remaining"))
def _hype_score_select(nbrs, fringe, bias, prev, *, select_k: int,
                       tile_g: int, interpret: bool,
                       with_remaining: bool = False):
    G, R, L = nbrs.shape
    tg = min(tile_g, G)
    pad = (-G) % tg
    if pad:
        nbrs = jnp.pad(nbrs, ((0, pad), (0, 0), (0, 0)),
                       constant_values=-1)
        fringe = jnp.pad(fringe, ((0, pad), (0, 0)), constant_values=-1)
        bias = jnp.pad(bias, ((0, pad), (0, 0)),
                       constant_values=jnp.inf)
        prev = jnp.pad(prev, ((0, pad), (0, 0)), constant_values=jnp.inf)
    out = hype_score_select_kernel(
        nbrs.reshape((G + pad) * R, L), fringe,
        bias.reshape((G + pad) * R), prev, select_k=select_k, tile_g=tg,
        interpret=interpret, with_remaining=with_remaining)
    scores, idx, val = out[:3]
    trimmed = (scores.reshape(G + pad, R)[:G], idx[:G], val[:G])
    if with_remaining:
        return trimmed + (out[3][:G],)
    return trimmed
