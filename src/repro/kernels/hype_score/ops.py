"""Jitted wrapper for the HYPE scoring kernel (auto-pad, auto-interpret)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import hype_scores_kernel


@functools.partial(jax.jit, static_argnames=("tile_b", "interpret"))
def hype_scores(nbrs, fringe, *, tile_b: int = 256, interpret=None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B = nbrs.shape[0]
    tile = min(tile_b, max(8, B))
    pad = (-B) % tile
    if pad:
        nbrs = jnp.pad(nbrs, ((0, pad), (0, 0)), constant_values=-1)
    out = hype_scores_kernel(nbrs, fringe, tile_b=tile, interpret=interpret)
    return out[:B]
