"""Batched HYPE external-neighbors scoring kernel (Pallas TPU).

TPU adaptation of the paper's score computation (§III-B2c): instead of the
CPU hash-set intersection, the fringe (s <= 16 vertices — the paper fixes
s = 10) is broadcast-compared against a tile of candidate neighbor lists
on the VPU:

    score[b] = #valid(nbrs[b,:]) - #(valid & in-fringe)

No gather, no hash set — one (TB, L, s) compare + two reductions per tile,
which is exactly the shape of work the VPU's 8x128 lanes want. This kernel
is what makes the *batched-candidate* HYPE variant (score r >> 2
candidates per step, pick top ones) profitable on TPU; the sequential
paper algorithm scores 2 candidates at a time and is latency-bound.

Tiles: nbrs (TB, L) in VMEM; fringe is tiny and replicated per tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401

from repro.kernels._compat import CompilerParams as _CompilerParams


def _score_kernel(fringe_ref, nbrs_ref, out_ref):
    nbrs = nbrs_ref[...]                      # (TB, L)
    fringe = fringe_ref[...]                  # (1, s)
    valid = nbrs >= 0
    member = jnp.zeros_like(valid)
    s = fringe.shape[-1]
    for j in range(s):                        # s is a small static constant
        member = jnp.logical_or(member, nbrs == fringe[0, j])
    member = jnp.logical_and(member, valid)
    score = valid.sum(axis=1) - member.sum(axis=1)
    out_ref[...] = score.astype(jnp.int32)[:, None]


def hype_scores_kernel(nbrs, fringe, *, tile_b: int = 256,
                       interpret: bool = False):
    """nbrs: (B, L) int32 (-1 pad, pre-deduped); fringe: (s,) int32."""
    B, L = nbrs.shape
    tile_b = min(tile_b, B)
    assert B % tile_b == 0, "pad B to a tile multiple"
    fringe2d = fringe[None, :]
    out = pl.pallas_call(
        _score_kernel,
        grid=(B // tile_b,),
        in_specs=[
            pl.BlockSpec((1, fringe.shape[0]), lambda i: (0, 0)),
            pl.BlockSpec((tile_b, L), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tile_b, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, 1), jnp.int32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(fringe2d, nbrs)
    return out[:, 0]
