"""Batched HYPE external-neighbors scoring kernel (Pallas TPU).

TPU adaptation of the paper's score computation (§III-B2c): instead of the
CPU hash-set intersection, the fringe (s <= 16 vertices — the paper fixes
s = 10) is broadcast-compared against a tile of candidate neighbor lists
on the VPU:

    score[b] = #valid(nbrs[b,:]) - #(valid & in-fringe)

No gather, no hash set — one (TB, L, s) compare + two reductions per tile,
which is exactly the shape of work the VPU's 8x128 lanes want. This kernel
is what makes the *batched-candidate* HYPE variant (score r >> 2
candidates per step, pick top ones) profitable on TPU; the sequential
paper algorithm scores 2 candidates at a time and is latency-bound.

Tiles: nbrs (TB, L) in VMEM; fringe is tiny and replicated per tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401

from repro.kernels._compat import CompilerParams as _CompilerParams


def _score_kernel(fringe_ref, nbrs_ref, out_ref):
    nbrs = nbrs_ref[...]                      # (TB, L)
    fringe = fringe_ref[...]                  # (1, s)
    valid = nbrs >= 0
    member = jnp.zeros_like(valid)
    s = fringe.shape[-1]
    for j in range(s):                        # s is a small static constant
        member = jnp.logical_or(member, nbrs == fringe[0, j])
    member = jnp.logical_and(member, valid)
    score = valid.sum(axis=1) - member.sum(axis=1)
    out_ref[...] = score.astype(jnp.int32)[:, None]


def hype_scores_kernel(nbrs, fringe, *, tile_b: int = 256,
                       interpret: bool = False):
    """nbrs: (B, L) int32 (-1 pad, pre-deduped); fringe: (s,) int32."""
    B, L = nbrs.shape
    tile_b = min(tile_b, B)
    assert B % tile_b == 0, "pad B to a tile multiple"
    fringe2d = fringe[None, :]
    out = pl.pallas_call(
        _score_kernel,
        grid=(B // tile_b,),
        in_specs=[
            pl.BlockSpec((1, fringe.shape[0]), lambda i: (0, 0)),
            pl.BlockSpec((tile_b, L), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tile_b, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, 1), jnp.int32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(fringe2d, nbrs)
    return out[:, 0]


# --------------------------------------------------------------------- #
# Fused score + select: the superstep engine's one-call-per-step kernel.
# --------------------------------------------------------------------- #

# Scores at or above this value are "not a candidate" (padded rows /
# empty pool slots). Finite so that exclusion during the running-argmin
# loop (set to +inf) stays distinguishable from a pad; any real score,
# including the 1e12 hub penalty, sits far below it.
SELECT_PAD = 1e30


def _score_select_kernel(fringe_ref, prev_ref, bias_ref, nbrs_ref,
                         score_ref, idx_ref, val_ref, rem_ref, *,
                         select_k: int, rows: int):
    """A *group* of growth phases per grid step: score + top-k select.

    The block stacks ``TG`` phases of ``rows`` fresh-candidate rows each.
    Scoring is exactly ``_score_kernel`` (fringe membership subtracted on
    the VPU, per-phase fringe rows) plus the per-row ``bias`` (hub
    penalty / +inf row pad). Selection then runs a running-argmin
    reduction in VMEM over each phase's scored rows *concatenated with*
    its held pool scores — vectorized across the TG phases of the block —
    so one kernel call performs refill-scoring plus the multi-admission
    selection the host used to argsort for. Selected indices < rows refer
    to fresh tile rows, >= rows to pool slots.
    """
    nbrs = nbrs_ref[...]                      # (TG * rows, L)
    fringe = fringe_ref[...]                  # (TG, s)
    prev = prev_ref[...]                      # (TG, P)
    tg = fringe.shape[0]
    valid = nbrs >= 0
    member = jnp.zeros_like(valid)
    for j in range(fringe.shape[-1]):         # s is a small static constant
        fj = jnp.repeat(fringe[:, j], rows)[:, None]   # phase -> its rows
        member = jnp.logical_or(member, nbrs == fj)
    member = jnp.logical_and(member, valid)
    score = (valid.sum(axis=1) - member.sum(axis=1)).astype(jnp.float32)
    score = score + bias_ref[...][:, 0]
    score_ref[...] = score[:, None]

    # merge fresh scores with the held pool scores; clamp +inf pads to the
    # finite SELECT_PAD so the exclusion sentinel (+inf) stays unique.
    merged = jnp.concatenate([score.reshape(tg, rows), prev], axis=1)
    merged = jnp.minimum(merged, jnp.float32(SELECT_PAD))
    n_slots = merged.shape[1]
    pos = jax.lax.broadcasted_iota(jnp.int32, merged.shape, 1)
    sel_i, sel_v = [], []
    for _ in range(select_k):                 # select_k is small and static
        mv = jnp.min(merged, axis=1, keepdims=True)          # (TG, 1)
        am = jnp.min(jnp.where(merged == mv, pos, n_slots), axis=1)
        sel_i.append(am)
        sel_v.append(mv[:, 0])
        merged = jnp.where(pos == am[:, None], jnp.float32(jnp.inf),
                           merged)
    idx_ref[...] = jnp.stack(sel_i, axis=1).astype(jnp.int32)
    val_ref[...] = jnp.stack(sel_v, axis=1).astype(jnp.float32)
    # refill trigger: real candidates left per phase AFTER selection
    # (selected slots are +inf, pads/empties sit at SELECT_PAD). The
    # device-resident loop reads this to decide which phases need a
    # pool refill next superstep without a host round-trip.
    rem_ref[...] = (merged < jnp.float32(SELECT_PAD)).sum(
        axis=1).astype(jnp.int32)[:, None]


def hype_score_select_kernel(nbrs, fringe, bias, prev, *, select_k: int,
                             tile_g: int = 8, interpret: bool = False,
                             with_remaining: bool = False):
    """Fused scoring + per-phase top-``select_k`` selection.

    nbrs:   (G*R, L) int32, -1 padded — G stacked phase tiles of R rows.
    fringe: (G, s)   int32, -1 padded — one fringe row per phase.
    bias:   (G*R,)   float32 — additive per-row bias (TRUNC_PENALTY for
            truncated hubs, +inf for absent/pad rows).
    prev:   (G, P)   float32 — held pool scores per phase (+inf = empty).

    ``tile_g`` phases are processed per grid step (selection vectorized
    across them); G must be a multiple of it — the jitted ``ops`` wrapper
    pads. Returns ``(scores, sel_idx, sel_val)``: scores (G*R,) f32
    (fresh rows, bias included); sel_idx (G, select_k) int32 into the
    phase's [fresh rows | pool slots] concatenation; sel_val
    (G, select_k) f32 (>= SELECT_PAD means "nothing there"). With
    ``with_remaining`` a fourth output rides along: remaining (G,) int32,
    the count of real candidate slots left per phase after selection —
    the refill-trigger flag source for the device-resident loop.
    """
    G, s = fringe.shape
    B, L = nbrs.shape
    assert B % G == 0, "stacked tile rows must divide evenly into phases"
    R = B // G
    P = prev.shape[1]
    assert prev.shape[0] == G and bias.shape == (B,)
    assert 1 <= select_k <= R + P
    tile_g = min(tile_g, G)
    assert G % tile_g == 0, "pad the phase count to a tile_g multiple"
    scores, idx, val, rem = pl.pallas_call(
        functools.partial(_score_select_kernel, select_k=select_k,
                          rows=R),
        grid=(G // tile_g,),
        in_specs=[
            pl.BlockSpec((tile_g, s), lambda g: (g, 0)),
            pl.BlockSpec((tile_g, P), lambda g: (g, 0)),
            pl.BlockSpec((tile_g * R, 1), lambda g: (g, 0)),
            pl.BlockSpec((tile_g * R, L), lambda g: (g, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tile_g * R, 1), lambda g: (g, 0)),
            pl.BlockSpec((tile_g, select_k), lambda g: (g, 0)),
            pl.BlockSpec((tile_g, select_k), lambda g: (g, 0)),
            pl.BlockSpec((tile_g, 1), lambda g: (g, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, 1), jnp.float32),
            jax.ShapeDtypeStruct((G, select_k), jnp.int32),
            jax.ShapeDtypeStruct((G, select_k), jnp.float32),
            jax.ShapeDtypeStruct((G, 1), jnp.int32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(fringe, prev, bias[:, None], nbrs)
    if with_remaining:
        return scores[:, 0], idx, val, rem[:, 0]
    return scores[:, 0], idx, val
