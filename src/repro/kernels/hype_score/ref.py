"""Oracle for batched external-neighbors scoring (paper Eq. 1).

d_ext(v, F) = |N(v) \\ F|: given pre-deduplicated padded neighbor lists
(the host's CSR machinery produces them), count valid neighbors not in
the fringe.
"""
from __future__ import annotations

import jax.numpy as jnp


def hype_scores_ref(nbrs, fringe):
    """nbrs: (B, L) int32, -1 padded; fringe: (s,) int32, -1 padded.

    Returns (B,) int32 external-neighbors scores.
    """
    valid = nbrs >= 0
    member = jnp.any(nbrs[..., None] == fringe[None, None, :], axis=-1)
    member &= valid
    return (valid.sum(-1) - member.sum(-1)).astype(jnp.int32)
