"""Oracle for batched external-neighbors scoring (paper Eq. 1).

d_ext(v, F) = |N(v) \\ F|: given pre-deduplicated padded neighbor lists
(the host's CSR machinery produces them), count valid neighbors not in
the fringe.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def hype_scores_ref(nbrs, fringe):
    """nbrs: (B, L) int32, -1 padded; fringe: (s,) int32, -1 padded.

    Returns (B,) int32 external-neighbors scores.
    """
    valid = nbrs >= 0
    member = jnp.any(nbrs[..., None] == fringe[None, None, :], axis=-1)
    member &= valid
    return (valid.sum(-1) - member.sum(-1)).astype(jnp.int32)


def hype_score_select_ref(nbrs, fringe, bias, prev, select_k):
    """Oracle for the fused score+select kernel (numpy, exact).

    nbrs: (G, R, L) int32; fringe: (G, s) int32; bias: (G, R) f32;
    prev: (G, P) f32. Returns ``(scores (G, R), sel_idx (G, select_k),
    sel_val (G, select_k))`` with the kernel's tie-break (lowest index
    first — a stable sort) and its +inf -> SELECT_PAD clamp.
    """
    from .kernel import SELECT_PAD

    nbrs, fringe = np.asarray(nbrs), np.asarray(fringe)
    bias, prev = np.asarray(bias), np.asarray(prev)
    valid = nbrs >= 0                                          # (G, R, L)
    member = np.any(nbrs[..., None] == fringe[:, None, None, :], axis=-1)
    member &= valid
    scores = (valid.sum(-1) - member.sum(-1)).astype(np.float32) + bias
    merged = np.minimum(np.concatenate([scores, prev], axis=1),
                        np.float32(SELECT_PAD))
    order = np.argsort(merged, axis=1, kind="stable")[:, :select_k]
    vals = np.take_along_axis(merged, order, axis=1)
    return scores, order.astype(np.int32), vals.astype(np.float32)
