from .ops import hype_score_select, hype_scores
