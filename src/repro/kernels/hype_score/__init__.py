from .ops import hype_scores
