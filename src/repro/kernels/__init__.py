# Pallas TPU kernels for the framework's compute hot-spots. Each package
# ships kernel.py (pl.pallas_call + explicit BlockSpec VMEM tiling),
# ops.py (jit wrapper, interpret=True off-TPU) and ref.py (pure-jnp
# oracle used by tests/benchmarks):
#   flash_attention — causal/SWA/GQA online-softmax attention (LM archs)
#   hype_score      — batched external-neighbors scoring (the paper's
#                     d_ext, VPU broadcast-compare formulation)
#   embedding_bag   — scalar-prefetch DMA gather-reduce (recsys)
#   neighbor_agg    — fused gather+mean+GEMM (sampled GNN minibatches)
