"""Fault-tolerant training driver.

Production posture for 1000+ nodes (see DESIGN.md §Scale):
  * checkpoint every ``ckpt_every`` steps via AsyncCheckpointer (I/O
    overlapped with compute; atomic rename publishing);
  * on ANY step failure: restore the last checkpoint and continue —
    the deterministic shard-aware data stream makes the replay exact;
  * elastic restart: checkpoints are stored unsharded, so a restart may
    claim a different device count / mesh shape and simply re-device_put;
  * straggler mitigation at the data tier (Prefetcher timeout re-serve)
    and at the step tier (skip-after-N-retries).

The same driver runs the real container-scale examples; the cluster
specifics (which process restarts, how the mesh is rebuilt) are the
launcher's job and documented rather than simulated here.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Callable, Iterator, Optional

import jax

from .checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint

log = logging.getLogger("repro.ft")


@dataclasses.dataclass
class FTConfig:
    ckpt_dir: str
    ckpt_every: int = 50
    max_retries_per_step: int = 2
    keep_last: int = 3


@dataclasses.dataclass
class TrainResult:
    steps_done: int
    failures_recovered: int
    metrics_history: list


def run_training(train_step: Callable, state: tuple, batches: Iterator,
                 n_steps: int, ft: FTConfig, *,
                 batch_at: Optional[Callable] = None,
                 fail_injector: Optional[Callable] = None) -> TrainResult:
    """Drive ``train_step`` for ``n_steps`` with checkpoint/restart.

    state = (params, opt_state[, err]); train_step(*state, batch) returns
    the updated state tuple with metrics dict appended.
    ``fail_injector(step)`` may raise to simulate node failure (tests).
    ``batch_at(step)`` enables exact replay after restore; otherwise the
    iterator is consumed forward (duplicates possible after restore —
    acceptable but not exact; tests use batch_at).
    """
    ckpt = AsyncCheckpointer(ft.ckpt_dir, keep_last=ft.keep_last)
    start = latest_step(ft.ckpt_dir)
    failures = 0
    history = []
    if start is not None:
        state = restore_checkpoint(ft.ckpt_dir, start, state)
        state = jax.tree.map(jax.numpy.asarray, state)
        log.info("restored checkpoint at step %d", start)
        step = start
    else:
        step = 0
    base = step  # history[i] is the metrics of step base + i

    while step < n_steps:
        batch = batch_at(step) if batch_at is not None else next(batches)
        retries = 0
        while True:
            try:
                if fail_injector is not None:
                    fail_injector(step)
                out = train_step(*state, batch)
                *new_state, metrics = out
                state = tuple(new_state)
                break
            except Exception as e:  # noqa: BLE001 — any device/host fault
                failures += 1
                retries += 1
                log.warning("step %d failed (%s); recovering", step, e)
                if retries > ft.max_retries_per_step:
                    log.error("step %d exceeded retries; skipping batch",
                              step)
                    metrics = {"loss": float("nan"), "skipped": True}
                    break
                restore = latest_step(ft.ckpt_dir)
                if restore is not None:
                    state = restore_checkpoint(ft.ckpt_dir, restore, state)
                    state = jax.tree.map(jax.numpy.asarray, state)
                    step = restore
                    # Rewind the metrics log with the step counter —
                    # the replayed steps re-append their metrics, so
                    # keeping the pre-failure entries would double-count
                    # every step between the checkpoint and the fault.
                    del history[max(0, step - base):]
                    batch = batch_at(step) if batch_at is not None \
                        else next(batches)
        history.append(jax.tree.map(
            lambda x: float(x) if hasattr(x, "item") else x, metrics))
        step += 1
        if step % ft.ckpt_every == 0 or step == n_steps:
            ckpt.save(step, state)
    ckpt.wait()
    return TrainResult(steps_done=step, failures_recovered=failures,
                       metrics_history=history)
