"""AdamW + gradient clipping + LR schedules, as pure pytree transforms.

No optax in this environment — the optimizer is implemented from scratch.
State layout mirrors the params pytree, so the same sharding specs apply
(fully sharded optimizer state = ZeRO over whatever mesh axes the params
use). ``moment_dtype`` lets very large models (llama3-405b) keep moments
in bf16 to fit HBM.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    moment_dtype: Optional[object] = None   # None -> fp32


class AdamWState(NamedTuple):
    step: jax.Array
    m: dict
    v: dict


def lr_at(cfg: AdamWConfig, step):
    """Linear warmup + cosine decay, computed in fp32 on device."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def init_adamw(params, cfg: AdamWConfig) -> AdamWState:
    dt = cfg.moment_dtype or jnp.float32
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def abstract_adamw(params_abstract, cfg: AdamWConfig) -> AdamWState:
    """ShapeDtypeStruct state (dry-run)."""
    dt = cfg.moment_dtype or jnp.float32
    zeros = lambda p: jax.ShapeDtypeStruct(p.shape, dt)
    return AdamWState(step=jax.ShapeDtypeStruct((), jnp.int32),
                      m=jax.tree.map(zeros, params_abstract),
                      v=jax.tree.map(zeros, params_abstract))


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def adamw_update(grads, state: AdamWState, params, cfg: AdamWConfig):
    """Returns (new_params, new_state, stats)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = state.step + 1
    lr = lr_at(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    mdt = cfg.moment_dtype or jnp.float32

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) \
            + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * delta
        return newp.astype(p.dtype), m32.astype(mdt), v32.astype(mdt)

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_params, AdamWState(step=step, m=new_m, v=new_v), {
        "grad_norm": gnorm, "lr": lr}
