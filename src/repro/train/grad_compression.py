"""Int8 gradient compression with error feedback (distributed-opt trick).

For data-parallel all-reduces at 1000+-node scale the gradient volume is
the dominant inter-pod traffic. We quantize each leaf to int8 with a
per-leaf fp32 scale before the (simulated) reduction and keep the
quantization residual in an error-feedback buffer added to the next step's
gradient — guaranteeing convergence (Karimireddy et al. 2019).

In the compiled train step, the quantize -> dequantize pair around the
pjit-inserted all-reduce lets XLA move the collective to the int8 tensor
(4x fewer inter-pod bytes). Enabled per-config with ``compress_grads``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_error_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_decompress(g, err):
    """Quantize g+err to int8, return (dequantized, new_err)."""
    g32 = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return deq.astype(g.dtype), g32 - deq


def compress_tree(grads, err_tree):
    out = jax.tree.map(compress_decompress, grads, err_tree)
    deq = jax.tree.map(lambda t: t[0], out,
                       is_leaf=lambda t: isinstance(t, tuple))
    err = jax.tree.map(lambda t: t[1], out,
                       is_leaf=lambda t: isinstance(t, tuple))
    return deq, err
