"""Train-step factory: grad accumulation, mixed precision, compression.

``make_train_step(loss_fn, opt_cfg, ...)`` returns a pure function

    train_step(params, opt_state, batch[, err]) -> (params, opt_state,
                                                    metrics[, err])

suitable for ``jax.jit`` with in/out shardings. Microbatching is a
``lax.scan`` over a leading accumulation axis of the batch: activations
live only for one microbatch; gradients accumulate in fp32.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from .optimizer import AdamWConfig, adamw_update
from .grad_compression import compress_tree


def make_train_step(loss_fn: Callable, opt_cfg: AdamWConfig, *,
                    accum_steps: int = 1, compress_grads: bool = False,
                    grad_shardings=None):
    """loss_fn(params, batch) -> scalar loss.

    ``grad_shardings``: optional pytree of NamedShardings matching params;
    constrains the fp32 accumulation buffers so they are stored sharded
    (without it XLA may replicate them — gigabytes at 100B+ scale).
    """

    def constrain_grads(g):
        if grad_shardings is None:
            return g
        return jax.tree.map(jax.lax.with_sharding_constraint, g,
                            grad_shardings)

    def grads_of(params, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        return loss, grads

    def train_step(params, opt_state, batch, err=None):
        if accum_steps > 1:
            # batch leaves have leading dim (accum_steps, ...)
            def micro(carry, mb):
                loss_acc, g_acc = carry
                loss, g = grads_of(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32) / accum_steps,
                    g_acc, g)
                return (loss_acc + loss / accum_steps,
                        constrain_grads(g_acc)), None
            g0 = constrain_grads(jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params))
            (loss, grads), _ = jax.lax.scan(micro, (jnp.float32(0), g0),
                                            batch)
        else:
            loss, grads = grads_of(params, batch)

        if compress_grads:
            assert err is not None
            grads, err = compress_tree(grads, err)

        params, opt_state, stats = adamw_update(grads, opt_state, params,
                                                opt_cfg)
        metrics = {"loss": loss.astype(jnp.float32), **stats}
        if compress_grads:
            return params, opt_state, metrics, err
        return params, opt_state, metrics

    return train_step


def split_microbatches(batch: dict, accum_steps: int) -> dict:
    """Reshape each leaf (B, ...) -> (accum, B/accum, ...)."""
    def f(x):
        b = x.shape[0]
        assert b % accum_steps == 0, (b, accum_steps)
        return x.reshape(accum_steps, b // accum_steps, *x.shape[1:])
    return jax.tree.map(f, batch)
