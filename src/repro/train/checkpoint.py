"""Sharded, atomic, resumable checkpointing (no orbax in this env).

Layout:  <dir>/step_<N>/
            manifest.json        # tree structure, shapes, dtypes, step
            leaf_<i>.npy         # one file per pytree leaf
         <dir>/LATEST            # atomic pointer file

Writes go to ``step_<N>.tmp`` then ``os.rename`` (atomic on POSIX), so a
crash mid-save can never corrupt the restore path. On a multi-host cluster
each host writes only the shards it owns (here: process 0 writes all,
matching the single-process container); restore reshards to any mesh since
leaves are stored unsharded — this is what makes *elastic* restarts
(different device count) work.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_paths(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save_checkpoint(ckpt_dir: str, step: int, tree: Any,
                    keep_last: int = 3) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves, treedef = _flatten_with_paths(tree)
    manifest = {"step": step, "n_leaves": len(leaves),
                "treedef": str(treedef),
                "leaves": []}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        # bf16 has no numpy dtype — store as uint16 view + tag
        if str(arr.dtype) == "bfloat16":
            np.save(os.path.join(tmp, f"leaf_{i}.npy"),
                    arr.view(np.uint16))
            manifest["leaves"].append({"dtype": "bfloat16",
                                       "shape": list(arr.shape)})
        else:
            np.save(os.path.join(tmp, f"leaf_{i}.npy"), arr)
            manifest["leaves"].append({"dtype": str(arr.dtype),
                                       "shape": list(arr.shape)})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                      # atomic publish
    latest_tmp = os.path.join(ckpt_dir, "LATEST.tmp")
    with open(latest_tmp, "w") as f:
        f.write(str(step))
    os.rename(latest_tmp, os.path.join(ckpt_dir, "LATEST"))
    _gc(ckpt_dir, keep_last)
    return final


def _gc(ckpt_dir: str, keep_last: int):
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for s in steps[:-keep_last]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    path = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        s = int(f.read().strip())
    if os.path.isdir(os.path.join(ckpt_dir, f"step_{s:08d}")):
        return s
    # pointer ahead of data (crash between renames): fall back to newest dir
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    return steps[-1] if steps else None


def restore_checkpoint(ckpt_dir: str, step: int, like: Any) -> Any:
    """Restore into the structure of ``like`` (shapes/dtypes verified).

    ``like`` may contain ShapeDtypeStructs or concrete arrays; restoring
    to a different mesh works because leaves are stored unsharded — the
    caller re-device_puts with its own shardings.
    """
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    leaves_like, treedef = jax.tree.flatten(like)
    assert len(leaves_like) == manifest["n_leaves"], \
        f"leaf count mismatch: {len(leaves_like)} vs {manifest['n_leaves']}"
    out = []
    for i, (meta, ref) in enumerate(zip(manifest["leaves"], leaves_like)):
        arr = np.load(os.path.join(d, f"leaf_{i}.npy"))
        if meta["dtype"] == "bfloat16":
            import ml_dtypes
            arr = arr.view(ml_dtypes.bfloat16)
        assert tuple(arr.shape) == tuple(ref.shape), \
            f"leaf {i}: shape {arr.shape} vs expected {ref.shape}"
        out.append(arr)
    return jax.tree.unflatten(treedef, out)


class AsyncCheckpointer:
    """Overlaps checkpoint I/O with training (one in-flight save)."""

    def __init__(self, ckpt_dir: str, keep_last: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep_last = keep_last
        self._thread: Optional[threading.Thread] = None

    def save(self, step: int, tree: Any):
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)
        self._thread = threading.Thread(
            target=save_checkpoint,
            args=(self.ckpt_dir, step, host_tree, self.keep_last),
            daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
