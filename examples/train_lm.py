"""Train a small LM with the full production stack on CPU.

    PYTHONPATH=src python examples/train_lm.py [--steps 200] [--dim 256]

Exercises: mesh + sharded train step, grad accumulation, AdamW with
cosine schedule, deterministic shard-aware data stream, fault-tolerant
driver with async checkpointing — the same code paths the dry-run proves
at 512 devices, here on 8 simulated CPU devices.
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import sys
sys.path.insert(0, "src")

import argparse
import tempfile

import jax
import jax.numpy as jnp

from repro.dist.sharding import LM_RULES, make_constrain
from repro.models.transformer import TransformerConfig, init_params, lm_loss
from repro.train.fault_tolerance import FTConfig, run_training
from repro.train.optimizer import AdamWConfig, init_adamw
from repro.train.train_loop import make_train_step, split_microbatches
from repro.data.pipeline import TokenStream


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--dim", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--accum", type=int, default=2)
    args = ap.parse_args()

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    cfg = TransformerConfig(
        name="demo", n_layers=args.layers, d_model=args.dim,
        n_heads=8, n_kv_heads=4, d_head=args.dim // 8, d_ff=args.dim * 4,
        vocab=4096, remat=False, dtype=jnp.float32,
        constrain=make_constrain(mesh, LM_RULES))
    print(f"model: {cfg.params_dense / 1e6:.1f}M params")

    params = init_params(jax.random.PRNGKey(0), cfg)
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps)
    opt = init_adamw(params, opt_cfg)
    step_fn = jax.jit(make_train_step(lambda p, b: lm_loss(p, b, cfg),
                                      opt_cfg, accum_steps=args.accum))

    stream = TokenStream(cfg.vocab, args.batch, args.seq, seed=0)

    def batch_at(step):
        b = stream.batch_at(step)
        return split_microbatches(
            {k: jnp.asarray(v) for k, v in b.items()}, args.accum)

    with tempfile.TemporaryDirectory() as ckpt_dir, mesh:
        res = run_training(step_fn, (params, opt), None, args.steps,
                           FTConfig(ckpt_dir=ckpt_dir, ckpt_every=50),
                           batch_at=batch_at)
    losses = [m["loss"] for m in res.metrics_history]
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} over "
          f"{res.steps_done} steps")
    assert losses[-1] < losses[0] - 0.5


if __name__ == "__main__":
    main()
