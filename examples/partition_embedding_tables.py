"""HYPE-partitioned embedding tables for distributed recsys serving.

    PYTHONPATH=src python examples/partition_embedding_tables.py

The paper's motivating application (§I: "minimizing the number of
transactions in distributed data placement"): embedding rows co-accessed
by one query form a hyperedge; HYPE places rows so queries touch few
shards. Demonstrates the full path: co-access log -> hypergraph -> HYPE ->
RowPlacement -> shard_map all-to-all lookup, and compares remote-lookup
traffic vs hash placement.
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import sys
sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.partitioned_embedding import (RowPlacement, assemble_bags,
                                              distributed_lookup,
                                              partition_rows_hype,
                                              route_queries)


def main():
    k, vocab, d, bag = 8, 4096, 64, 16
    rng = np.random.default_rng(0)

    # co-access log: queries touch correlated row neighborhoods
    n_q = 3000
    centers = rng.integers(0, vocab, n_q)
    queries = [np.unique((centers[i] + rng.geometric(0.08, bag)) % vocab)
               for i in range(n_q)]

    print("partitioning rows with HYPE (co-access hypergraph) ...")
    asg_hype = partition_rows_hype(vocab, queries, k, seed=0)
    asg_hash = (np.arange(vocab) * 2654435761 % vocab % k).astype(np.int32)

    table = rng.normal(size=(vocab, d)).astype(np.float32)
    mesh = jax.make_mesh((k,), ("devices",))

    for name, asg in (("hype", asg_hype), ("hash", asg_hash)):
        pl_ = RowPlacement.from_assignment(asg, k)
        tables = jnp.asarray(pl_.shard_table(table))

        # placement metrics under AFFINITY routing: each query is served
        # by the shard owning most of its rows (this is the (k-1)-style
        # objective HYPE optimizes: shards touched per query)
        touched, remote = [], []
        for i in range(n_q):
            counts = np.bincount(pl_.owner[queries[i]], minlength=k)
            touched.append(int((counts > 0).sum()))
            remote.append(1.0 - counts.max() / max(counts.sum(), 1))
        print(f"{name:5s}: shards touched/query = {np.mean(touched):.2f}, "
              f"remote-lookup fraction (affinity-routed) = "
              f"{np.mean(remote):.3f}")

        # run one real distributed lookup round-trip on shard 0
        ids = np.full((4, bag), -1, np.int64)
        for r in range(4):
            q = queries[rng.integers(0, n_q)]
            ids[r, :min(len(q), bag)] = q[:bag]
        reqs, backs = [], []
        for shard in range(k):
            req, back, _ = route_queries(pl_, ids, shard, q_max=bag)
            reqs.append(req)
            backs.append(back)
        resp = distributed_lookup(tables, jnp.asarray(np.stack(reqs)), mesh)
        out0 = np.asarray(assemble_bags(resp[0], jnp.asarray(backs[0]),
                                        (4, bag)))
        valid = ids >= 0
        vecs = table[np.where(valid, ids, 0)] * valid[..., None]
        expect = vecs.sum(1) / np.maximum(valid.sum(1), 1)[:, None]
        assert np.allclose(out0, expect, atol=1e-5), "lookup mismatch"

    print("\nHYPE placement clusters each query's rows on few shards; "
          "hash placement scatters every query across ~all shards.")


if __name__ == "__main__":
    main()
