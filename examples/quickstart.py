"""Quickstart: partition a hypergraph with HYPE and compare baselines.

    PYTHONPATH=src python examples/quickstart.py

Reproduces the paper's headline on a synthetic power-law hypergraph:
HYPE's structure-aware growth beats streaming MinMax and random placement
on the (k-1) metric with perfect vertex balance.
"""
import sys
sys.path.insert(0, "src")

import time

from repro.core import metrics
from repro.core.partition_api import partition
from repro.data.synthetic import github_like


def main():
    print("generating github-scale power-law hypergraph ...")
    hg = github_like(scale=0.25, seed=7)
    print(f"  n={hg.n:,} vertices, m={hg.m:,} hyperedges, "
          f"pins={hg.n_pins:,}")

    k = 32
    print(f"\npartitioning into k={k} parts:\n")
    print(f"{'method':<16}{'(k-1) cut':>12}{'imbalance':>12}{'runtime':>10}")
    for method in ("random", "minmax_eb", "minmax_nb", "hype",
                   "hype_batched", "hype_superstep"):
        t0 = time.perf_counter()
        a = partition(hg, k, method, seed=0)
        dt = time.perf_counter() - t0
        km1 = metrics.k_minus_1(hg, a)
        imb = metrics.vertex_imbalance(a, k)
        print(f"{method:<16}{km1:>12,}{imb:>12.3f}{dt:>9.2f}s")

    print("\nHYPE: lowest cut at perfect balance — the paper's claim.")
    print("hype_batched: same quality regime, kernel-batched scoring "
          "(see DESIGN.md §4).")
    print("hype_superstep: the engine knob for large k — all 32 parts "
          "grow concurrently\n  against a device-resident graph image, "
          "one fused score+select call per superstep\n  (DESIGN.md "
          "§4b); tune with t / rows / pool_cap, e.g.\n  "
          "partition(hg, k, 'hype_superstep', t=16, rows=8).")


if __name__ == "__main__":
    main()
