"""Batched LM serving demo: prefill + decode with KV cache.

    PYTHONPATH=src python examples/serve_lm.py

Runs continuous batched decoding for a small model: prefill a batch of
prompts, then decode tokens step by step with the rolling/linear cache —
the same serve_step the dry-run lowers for decode_32k / long_500k.
Verifies decode logits match the full-forward oracle.
"""
import sys
sys.path.insert(0, "src")

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import (TransformerConfig, forward,
                                      init_params, prefill, serve_step)


def main():
    cfg = TransformerConfig(
        name="serve-demo", n_layers=4, d_model=256, n_heads=8, n_kv_heads=2,
        d_head=32, d_ff=1024, vocab=4096, window=64, remat=False,
        dtype=jnp.float32)
    params = init_params(jax.random.PRNGKey(0), cfg)
    B, S, new_tokens = 8, 48, 32

    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)

    prefill_j = jax.jit(lambda p, t: prefill(p, t, cfg))
    decode_j = jax.jit(lambda p, c, t: serve_step(p, c, t, cfg))

    t0 = time.perf_counter()
    cache, logits = prefill_j(params, prompts)
    cache = dict(cache)
    # extend rolling buffer to full window if prompt shorter
    Skv = cfg.window
    if cache["k"].shape[2] < Skv:
        pad = Skv - cache["k"].shape[2]
        cache["k"] = jnp.pad(cache["k"], ((0, 0), (0, 0), (0, pad),
                                          (0, 0), (0, 0)))
        cache["v"] = jnp.pad(cache["v"], ((0, 0), (0, 0), (0, pad),
                                          (0, 0), (0, 0)))
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    # greedy decode
    out_tokens = []
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    t0 = time.perf_counter()
    for _ in range(new_tokens):
        out_tokens.append(tok)
        logits, cache = decode_j(params, cache, tok)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    jax.block_until_ready(logits)
    t_decode = time.perf_counter() - t0

    gen = jnp.concatenate(out_tokens, axis=1)
    print(f"prefill {B}x{S} in {t_prefill * 1e3:.1f} ms; "
          f"decoded {new_tokens} tokens/seq in {t_decode * 1e3:.1f} ms "
          f"({B * new_tokens / t_decode:.0f} tok/s)")

    # correctness: first decoded step == oracle next-token from full fwd
    x, _ = forward(params, prompts, cfg)
    oracle = jnp.argmax(x[:, -1] @ params["lm_head"], -1)
    match = float((gen[:, 0] == oracle).mean())
    print(f"decode vs full-forward argmax agreement: {match:.2f}")
    assert match == 1.0


if __name__ == "__main__":
    main()
