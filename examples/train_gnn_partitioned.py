"""End-to-end driver: HYPE-partitioned distributed GNN training.

    PYTHONPATH=src python examples/train_gnn_partitioned.py [--steps 300]

The paper's technique doing its actual job:
  1. generate a community-structured graph;
  2. build its neighborhood hypergraph and partition nodes with HYPE;
  3. train a GraphSAGE node classifier for a few hundred steps where every
     layer's aggregation runs through the shard_map halo exchange
     (all-to-all volume set by partition quality);
  4. report the learned accuracy and the traffic savings vs random
     placement.

Runs on this container's CPU with 8 simulated devices.
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import sys
sys.path.insert(0, "src")

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hype import HypeParams
from repro.core.minmax import random_partition
from repro.dist.partitioned_gnn import (build_partitioned_graph,
                                        graph_to_hypergraph, halo_aggregate,
                                        partition_graph_hype,
                                        scatter_to_parts)
from repro.models.common import softmax_cross_entropy
from repro.train.optimizer import AdamWConfig, adamw_update, init_adamw


def community_graph(n, n_comm, deg, rng):
    """Graph with contiguous planted communities + weak global edges."""
    block = n // n_comm
    comm = np.arange(n) // block
    comm = np.minimum(comm, n_comm - 1)
    src = rng.integers(0, n, n * deg)
    local = rng.random(n * deg) < 0.985
    near = (src + rng.integers(1, max(block // 4, 2), n * deg)) % n
    far = rng.integers(0, n, n * deg)
    dst = np.where(local, near, far)
    keep = src != dst
    return src[keep].astype(np.int32), dst[keep].astype(np.int32), comm


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--n", type=int, default=2000)
    ap.add_argument("--k", type=int, default=8)
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    n, k, d, n_classes = args.n, args.k, 64, 8
    src, dst, comm = community_graph(n, 16, 8, rng)
    print(f"graph: n={n} edges={src.size}")

    # --- HYPE placement (boundary all-gather exchange) ---
    t0 = time.perf_counter()
    asg = partition_graph_hype(n, src, dst, k, seed=0)
    pg = build_partitioned_graph(n, src, dst, asg, k, mode="allgather")
    pg_rand = build_partitioned_graph(
        n, src, dst,
        random_partition(graph_to_hypergraph(n, src, dst), k, seed=0), k,
        mode="allgather")
    rf_h = pg.stats["remote_edge_frac"]
    rf_r = pg_rand.stats["remote_edge_frac"]
    print(f"HYPE placement in {time.perf_counter() - t0:.1f}s: "
          f"remote-edge fraction {rf_h:.2f} vs random {rf_r:.2f} "
          f"({rf_h / max(rf_r, 1e-9):.2f}x cross-device message traffic); "
          f"boundary B_max {pg.s_max} vs {pg_rand.s_max}")

    mesh = jax.make_mesh((k,), ("devices",))

    # features carry community signal + noise; labels = community % classes
    proto = rng.normal(size=(16, d)).astype(np.float32)
    x = (proto[comm] + rng.normal(size=(n, d)) * 1.0).astype(np.float32)
    labels = (comm % n_classes).astype(np.int32)

    xp = jnp.asarray(scatter_to_parts(pg, x))
    yp = jnp.asarray(scatter_to_parts(pg, labels[:, None].astype(np.float32))
                     )[..., 0].astype(jnp.int32)
    maskp = jnp.asarray(pg.node_mask)
    pga = {k2: jnp.asarray(getattr(pg, k2)) for k2 in
           ("send_idx", "edge_src_local", "edge_dst_local", "edge_mask")}

    # --- 2-layer GraphSAGE on the partitioned layout ---
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 5)
    params = {
        "w1s": jax.random.normal(ks[0], (d, 64)) * 0.1,
        "w1n": jax.random.normal(ks[1], (d, 64)) * 0.1,
        "w2s": jax.random.normal(ks[2], (64, 64)) * 0.1,
        "w2n": jax.random.normal(ks[3], (64, 64)) * 0.1,
        "dec": jax.random.normal(ks[4], (64, n_classes)) * 0.1,
    }
    opt_cfg = AdamWConfig(lr=3e-3, warmup_steps=20, total_steps=args.steps,
                          weight_decay=0.0)
    opt = init_adamw(params, opt_cfg)

    def loss_fn(p, xp):
        agg1 = halo_aggregate(pga, xp, lambda h: h, mesh, mode="allgather")
        h = jax.nn.relu(xp @ p["w1s"] + agg1 @ p["w1n"])
        agg2 = halo_aggregate(pga, h, lambda h: h, mesh, mode="allgather")
        h2 = jax.nn.relu(h @ p["w2s"] + agg2 @ p["w2n"])
        logits = h2 @ p["dec"]
        m = maskp.astype(jnp.float32)
        return softmax_cross_entropy(logits, yp, mask=m)

    @jax.jit
    def step(p, opt, xp):
        loss, g = jax.value_and_grad(loss_fn)(p, xp)
        p, opt, stats = adamw_update(g, opt, p, opt_cfg)
        return p, opt, loss

    t0 = time.perf_counter()
    for i in range(args.steps):
        params, opt, loss = step(params, opt, xp)
        if i % 50 == 0 or i == args.steps - 1:
            print(f"step {i:4d}  loss {float(loss):.4f}")
    dt = time.perf_counter() - t0
    print(f"trained {args.steps} steps in {dt:.1f}s "
          f"({args.steps / dt:.1f} steps/s)")

    # accuracy
    agg1 = halo_aggregate(pga, xp, lambda h: h, mesh, mode="allgather")
    h = jax.nn.relu(xp @ params["w1s"] + agg1 @ params["w1n"])
    agg2 = halo_aggregate(pga, h, lambda h: h, mesh, mode="allgather")
    h2 = jax.nn.relu(h @ params["w2s"] + agg2 @ params["w2n"])
    pred = jnp.argmax(h2 @ params["dec"], -1)
    acc = float((jnp.where(maskp, pred == yp, False)).sum()
                / maskp.sum())
    print(f"train accuracy: {acc:.3f} (classes={n_classes})")
    assert acc > 0.5, "should be well above chance"


if __name__ == "__main__":
    main()
