"""Docs gate: markdown link integrity + a runnable README quickstart.

    python tools/check_docs.py links                 # stdlib only
    python tools/check_docs.py quickstart            # needs jax + numpy
    python tools/check_docs.py quickstart --print    # show the snippet

``links`` walks the repo's documentation surface (README.md, DESIGN.md,
CHANGES.md, ROADMAP.md, benchmarks/README.md) and fails on any
relative link/path reference whose target file does not exist — so docs
cannot point at renamed modules. External http(s) links are not
fetched.

``quickstart`` extracts the FIRST fenced ``python`` block of README.md
and executes it with the repo's ``src`` on ``sys.path`` — the
documented entry point can never rot. The block must be self-contained.
"""
from __future__ import annotations

import pathlib
import re
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent
DOCS = ["README.md", "DESIGN.md", "CHANGES.md", "ROADMAP.md",
        "benchmarks/README.md"]

_LINK = re.compile(r"\[[^\]]*\]\(([^)#]+)(?:#[^)]*)?\)")
_REF = re.compile(r"`([A-Za-z0-9_./-]+\.(?:py|md|json|yml))`")


def check_links() -> int:
    bad = []
    for doc in DOCS:
        path = REPO / doc
        if not path.exists():
            bad.append(f"{doc}: documentation file missing")
            continue
        text = path.read_text()
        targets = set(_LINK.findall(text)) | set(_REF.findall(text))
        for t in sorted(targets):
            if t.startswith(("http://", "https://", "mailto:")):
                continue
            # docs refer to code by doc-relative path, repo path, or
            # package path (`core/hype.py`); a bare module name
            # (`hype.py`) resolves if the file exists anywhere — the
            # point is catching renames, not pinning directories.
            roots = (path.parent, REPO, REPO / "src" / "repro")
            if any((r / t).exists() for r in roots):
                continue
            if "/" not in t and list(REPO.rglob(t)):
                continue
            bad.append(f"{doc}: broken reference -> {t}")
    if bad:
        print("FAIL: broken documentation references:")
        for b in bad:
            print(f"  {b}")
        return 1
    print(f"OK: all file references in {', '.join(DOCS)} resolve")
    return 0


def extract_quickstart() -> str:
    text = (REPO / "README.md").read_text()
    m = re.search(r"```python\n(.*?)```", text, re.DOTALL)
    if not m:
        raise SystemExit("FAIL: README.md has no ```python quickstart "
                         "block")
    return m.group(1)


def run_quickstart(show: bool = False) -> int:
    snippet = extract_quickstart()
    if show:
        print(snippet)
        return 0
    sys.path.insert(0, str(REPO / "src"))
    print("# executing README.md quickstart block:")
    exec(compile(snippet, "README.md:quickstart", "exec"), {})  # noqa: S102
    print("OK: README quickstart executed")
    return 0


def main(argv) -> int:
    if len(argv) < 2 or argv[1] not in ("links", "quickstart"):
        print(__doc__)
        return 2
    if argv[1] == "links":
        return check_links()
    return run_quickstart(show="--print" in argv)


if __name__ == "__main__":
    sys.exit(main(sys.argv))
