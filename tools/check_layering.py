#!/usr/bin/env python
"""Static import-layering lint for the engine split (DESIGN.md §1).

The package layout separates the shared core (``repro.core``,
``repro.kernels``) from the per-engine modules in ``repro.engines``.
Two rules keep the layering acyclic and the engines independent, and
this lint enforces them on *module top-level* imports only (function-
level lazy imports are the sanctioned escape hatch — dispatch tables
and fallback chains resolve engines at call time):

  1. ``repro.core`` (and anything under it) never imports
     ``repro.engines`` at module level. The core is the layer below;
     ``partition_api`` reaches the engines through lazy resolvers.
  2. Engine modules may import the shared engine layer
     (``repro.engines.runtime``, ``repro.engines.pipeline``) and the
     core/kernels freely, but from a *sibling* engine module they may
     only ``from``-import public (non-underscore) names — the Params
     inheritance chain and the fallback entry points. Binding a sibling
     module object (``import repro.engines.batched`` or
     ``from repro.engines import batched``) or importing a private
     name reaches into another engine's internals and is rejected.
     ``runtime``/``pipeline`` themselves sit below every engine and may
     not import any of them.

Exit status 0 when ``src/repro`` is clean, 1 with one line per
violation otherwise. ``violations_for_source`` is importable for tests.
"""
from __future__ import annotations

import ast
import pathlib
import sys
from typing import List, Tuple

ENGINES_PKG = "repro.engines"
# the shared engine layer: importable from every engine module
SHARED = {f"{ENGINES_PKG}.runtime", f"{ENGINES_PKG}.pipeline"}


def _resolve(modname: str, node: ast.ImportFrom) -> str:
    """Absolute target of an ``ImportFrom`` found in module ``modname``."""
    if node.level == 0:
        return node.module or ""
    parts = modname.split(".")[:-node.level]
    if node.module:
        parts.append(node.module)
    return ".".join(parts)


def _in_pkg(target: str, pkg: str) -> bool:
    return target == pkg or target.startswith(pkg + ".")


def _top_level_imports(tree: ast.Module):
    """Yield Import/ImportFrom nodes outside any function body."""
    stack = list(tree.body)
    while stack:
        node = stack.pop()
        if isinstance(node, (ast.Import, ast.ImportFrom)):
            yield node
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue                      # lazy imports are sanctioned
        else:
            stack.extend(ast.iter_child_nodes(node))


def violations_for_source(modname: str,
                          source: str) -> List[Tuple[int, str]]:
    """Lint one module; returns ``[(lineno, message), ...]``."""
    tree = ast.parse(source)
    out: List[Tuple[int, str]] = []
    in_core = _in_pkg(modname, "repro.core")
    is_shared = modname in SHARED
    is_engine = (_in_pkg(modname, ENGINES_PKG)
                 and modname != ENGINES_PKG and not is_shared)

    for node in _top_level_imports(tree):
        if isinstance(node, ast.Import):
            targets = [(a.name, None) for a in node.names]
        else:
            tgt = _resolve(modname, node)
            targets = [(tgt, a.name) for a in node.names]
        for tgt, name in targets:
            if not _in_pkg(tgt, ENGINES_PKG):
                continue
            if in_core:
                out.append((node.lineno,
                            f"{modname}: repro.core may not import "
                            f"{tgt} at module level (layering rule 1)"))
            elif is_shared and tgt != modname and not (
                    tgt in SHARED or tgt == ENGINES_PKG):
                out.append((node.lineno,
                            f"{modname}: the shared engine layer may "
                            f"not import engine module {tgt}"))
            elif is_engine:
                # sibling = engine module other than self / shared layer
                if tgt == ENGINES_PKG:
                    sib = name is not None and name != "*" and \
                        f"{ENGINES_PKG}.{name}" not in SHARED
                    if isinstance(node, ast.Import) or sib:
                        out.append((node.lineno,
                                    f"{modname}: binds engine module "
                                    f"object {tgt}.{name or ''} — "
                                    f"import its public names instead"))
                    continue
                if tgt in SHARED or tgt == modname:
                    continue
                if isinstance(node, ast.Import):
                    out.append((node.lineno,
                                f"{modname}: binds sibling engine "
                                f"module {tgt} — from-import its "
                                f"public names instead"))
                elif name == "*" or name.startswith("_"):
                    out.append((node.lineno,
                                f"{modname}: imports non-public name "
                                f"{name!r} from sibling engine {tgt}"))
    return out


def check_tree(src_root: pathlib.Path) -> List[str]:
    """Lint every module under ``src_root/repro``; returns messages."""
    msgs = []
    for path in sorted((src_root / "repro").rglob("*.py")):
        rel = path.relative_to(src_root).with_suffix("")
        parts = list(rel.parts)
        if parts[-1] == "__init__":
            parts = parts[:-1]
        modname = ".".join(parts)
        for lineno, msg in violations_for_source(modname,
                                                 path.read_text()):
            msgs.append(f"{path}:{lineno}: {msg}")
    return msgs


def main(argv=None) -> int:
    root = pathlib.Path(__file__).resolve().parent.parent / "src"
    msgs = check_tree(root)
    for msg in msgs:
        print(msg, file=sys.stderr)
    if msgs:
        print(f"check_layering: {len(msgs)} violation(s)",
              file=sys.stderr)
        return 1
    print("check_layering: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
